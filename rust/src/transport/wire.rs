//! The AMTL wire protocol: versioned, length-prefixed, checksummed binary
//! frames carrying the messages of Algorithm 1's star topology — the four
//! algorithmic messages (`FetchProxCol`/`PushUpdate`/`FetchEta`/`Shutdown`)
//! plus the elastic-membership frames (`Register`/`Heartbeat`/`Leave`)
//! that let task nodes join, prove liveness, and depart mid-run, the
//! serving-tier frames (`Predict`/`FetchStats`) spoken by read replicas
//! (see [`serve`](crate::serve)), and the observability frame pair
//! (`FetchMetrics` → [`MetricsReport`]) answered by **both** the trainer
//! and the replica (see [`obs`](crate::obs) and `amtl top`).
//!
//! Every frame is
//!
//! ```text
//! ┌───────┬─────────┬────────┬──────────┬───────────┬──────────┐
//! │ magic │ version │ opcode │ len(u32) │ payload   │ crc(u32) │
//! │ 4 B   │ 1 B     │ 1 B    │ 4 B LE   │ len bytes │ 4 B LE   │
//! └───────┴─────────┴────────┴──────────┴───────────┴──────────┘
//! ```
//!
//! with `magic = b"AMTL"`, `version = 1`, and `crc` the FNV-1a (32-bit)
//! checksum of `version ‖ opcode ‖ len ‖ payload` — every header or payload
//! corruption downstream of the magic is caught either by an explicit field
//! check or by the checksum. All multi-byte integers and every `f64` are
//! little-endian. There are no external dependencies: the codec is plain
//! `std`, and decoding NEVER panics on malformed input — truncated,
//! oversized, or corrupted frames return a [`WireError`].
//!
//! What crosses the wire is only what the paper's privacy argument allows:
//! model vectors (prox columns, forward-step results) and scalars (η, KM
//! steps, version counters). Task *training* data (`X_t`, `y_t`) has no
//! frame type at all — it *cannot* be transmitted by this protocol. The
//! serving-tier `Predict` frame carries a feature vector, but it is the
//! *querier's own* input (the "user request" of the deployment story),
//! sent voluntarily to a replica to be scored — no frame moves a task
//! node's training set anywhere.

use crate::obs::hist::{HistSnapshot, BUCKETS};
use std::fmt;
use std::io::{Read, Write};

/// Frame prefix identifying the protocol.
pub const MAGIC: [u8; 4] = *b"AMTL";
/// Current protocol version; bumped on any incompatible frame change.
/// v2: `PushUpdate` carries the node's activation counter `k` (commit
/// dedup key for at-least-once resends) and the membership frames
/// (`Register`/`Heartbeat`/`Leave`) exist. The serving-tier frames
/// (`Predict`/`FetchStats`) and the observability frames
/// (`FetchMetrics`/`Metrics`) are *additive* extensions — new opcodes,
/// same version: decoders reject opcodes they don't know, so older peers
/// refuse the new frames cleanly without a version bump.
/// v3: `PushUpdate` carries the commit's cross-process span id (same
/// pattern as the v2 activation counter — a field change forces the
/// bump), `MetricsReport` fans in per-node sub-reports (role `NODE`
/// rows), and worker processes piggyback their registry on the new
/// `PushMetrics`/`MetricsAck` opcode pair. The sharded-server frames
/// (`FetchShardMap`/`ShardMap`, `PushBatch`/`PushedBatch`,
/// `FetchSlice`/`Slice`, `PushProxSlice`/`ProxSliceAck` — see
/// [`shard`](crate::shard)) are additive opcodes on v3: no existing
/// frame changed layout, so pre-shard peers keep decoding everything
/// they already spoke and refuse the new opcodes cleanly.
pub const VERSION: u8 = 3;
/// Upper bound on payload size (guards allocation on corrupted lengths:
/// 64 MiB ≫ any model column we ship).
pub const MAX_PAYLOAD: u32 = 1 << 26;

// Request opcodes (client → server).
const OP_FETCH_PROX_COL: u8 = 0x01;
const OP_PUSH_UPDATE: u8 = 0x02;
const OP_FETCH_ETA: u8 = 0x03;
const OP_SHUTDOWN: u8 = 0x04;
const OP_REGISTER: u8 = 0x05;
const OP_HEARTBEAT: u8 = 0x06;
const OP_LEAVE: u8 = 0x07;
const OP_PREDICT: u8 = 0x08;
const OP_FETCH_STATS: u8 = 0x09;
const OP_FETCH_METRICS: u8 = 0x0A;
const OP_PUSH_METRICS: u8 = 0x0B;
const OP_FETCH_SHARD_MAP: u8 = 0x0C;
const OP_PUSH_BATCH: u8 = 0x0D;
const OP_FETCH_SLICE: u8 = 0x0E;
const OP_PUSH_PROX_SLICE: u8 = 0x0F;

// Response opcodes (server → client).
const OP_PROX_COL: u8 = 0x81;
const OP_PUSHED: u8 = 0x82;
const OP_ETA: u8 = 0x83;
const OP_SHUTDOWN_ACK: u8 = 0x84;
const OP_REGISTERED: u8 = 0x85;
const OP_HEARTBEAT_ACK: u8 = 0x86;
const OP_LEAVE_ACK: u8 = 0x87;
const OP_PREDICTION: u8 = 0x88;
const OP_STATS: u8 = 0x89;
const OP_METRICS: u8 = 0x8A;
const OP_METRICS_ACK: u8 = 0x8B;
const OP_SHARD_MAP: u8 = 0x8C;
const OP_PUSHED_BATCH: u8 = 0x8D;
const OP_SLICE: u8 = 0x8E;
const OP_PROX_SLICE_ACK: u8 = 0x8F;
const OP_ERROR: u8 = 0xFF;

/// Decode/IO failure. Malformed input is an error, never a panic.
#[derive(Debug)]
pub enum WireError {
    /// Underlying socket/stream failure (includes clean EOF).
    Io(std::io::Error),
    /// Frame did not start with the protocol magic.
    BadMagic([u8; 4]),
    /// Peer speaks a different protocol version.
    BadVersion(u8),
    /// Unknown message opcode.
    BadOpcode(u8),
    /// Declared payload length exceeds the frame size cap.
    Oversize(u32),
    /// FNV checksum mismatch (corrupt payload).
    BadChecksum {
        /// Checksum computed over the received payload.
        got: u32,
        /// Checksum declared in the frame header.
        want: u32,
    },
    /// Structurally invalid payload for the opcode.
    Malformed(&'static str),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (expected {VERSION})")
            }
            WireError::BadOpcode(op) => write!(f, "unknown opcode {op:#04x}"),
            WireError::Oversize(n) => {
                write!(f, "payload length {n} exceeds maximum {MAX_PAYLOAD}")
            }
            WireError::BadChecksum { got, want } => {
                write!(f, "checksum mismatch: frame says {want:#010x}, computed {got:#010x}")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// FNV-1a 32-bit over a sequence of byte slices. Shared with the
/// [`persist`](crate::persist) codec, so wire frames and durable records
/// are protected by the same (well-tested) checksum.
pub(crate) fn fnv1a32(chunks: &[&[u8]]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u32;
            h = h.wrapping_mul(0x01000193);
        }
    }
    h
}

/// Write one frame: header, payload, checksum.
pub fn write_frame(w: &mut impl Write, opcode: u8, payload: &[u8]) -> Result<(), WireError> {
    debug_assert!(payload.len() as u64 <= MAX_PAYLOAD as u64);
    let len = (payload.len() as u32).to_le_bytes();
    let crc = fnv1a32(&[&[VERSION, opcode], &len, payload]).to_le_bytes();
    w.write_all(&MAGIC)?;
    w.write_all(&[VERSION, opcode])?;
    w.write_all(&len)?;
    w.write_all(payload)?;
    w.write_all(&crc)?;
    Ok(())
}

/// Read one frame, verifying magic, version, size bound, and checksum.
/// Returns `(opcode, payload)`; the opcode is validated by the message
/// decoders ([`Request::decode`] / [`Response::decode`]).
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), WireError> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let mut head = [0u8; 6]; // version, opcode, len
    r.read_exact(&mut head)?;
    if head[0] != VERSION {
        return Err(WireError::BadVersion(head[0]));
    }
    let opcode = head[1];
    let len = u32::from_le_bytes([head[2], head[3], head[4], head[5]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversize(len));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc)?;
    let want = u32::from_le_bytes(crc);
    let got = fnv1a32(&[&head, &payload]);
    if got != want {
        return Err(WireError::BadChecksum { got, want });
    }
    Ok((opcode, payload))
}

// ------------------------------------------------------- payload cursor

/// Bounds-checked little-endian reader over a payload slice. Shared with
/// the [`persist`](crate::persist) codec (snapshot/WAL records reuse the
/// wire framing discipline).
pub(crate) struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, i: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or(WireError::Malformed("payload shorter than declared field"))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// All remaining bytes, verbatim (opaque payload tails).
    pub(crate) fn take_rest(&mut self) -> &'a [u8] {
        let rest = &self.b[self.i..];
        self.i = self.b.len();
        rest
    }

    /// All remaining bytes as a little-endian f64 vector.
    pub(crate) fn rest_f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let rest = &self.b[self.i..];
        if rest.len() % 8 != 0 {
            return Err(WireError::Malformed("f64 vector length not a multiple of 8"));
        }
        self.i = self.b.len();
        Ok(rest
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])))
            .collect())
    }

    pub(crate) fn finish(self) -> Result<(), WireError> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes after payload"))
        }
    }
}

pub(crate) fn push_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(xs.len() * 8);
    for x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

// ------------------------------------------------------------- messages

/// A read replica's self-description, served in reply to
/// [`Request::FetchStats`]: model shape, how far behind the trainer it is
/// (lag, in commit sequence numbers), and its request-side counters +
/// latency quantiles. All fields are plain scalars so the frame is
/// fixed-size and additive changes stay easy to audit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaStats {
    /// Number of tasks T the serving model routes across.
    pub tasks: u32,
    /// Feature dimension d of every per-task model column.
    pub dim: u32,
    /// Commit sequence number the *serving* model incorporates.
    pub model_seq: u64,
    /// Newest commit sequence number the replica has observed on disk
    /// (advances ahead of `model_seq` while a drain batch is in flight).
    pub latest_seq: u64,
    /// WAL entries applied since bootstrap (across hot-swaps).
    pub applied_entries: u64,
    /// Predict requests answered successfully.
    pub predictions: u64,
    /// Predict requests rejected (bad task index, dimension mismatch).
    pub errors: u64,
    /// Snapshot bootstraps performed (1 after a clean start).
    pub bootstraps: u64,
    /// Re-bootstraps forced by checkpoint rotation pruning the WAL tail
    /// out from under the replica.
    pub hot_swaps: u64,
    /// Median per-request service latency, microseconds (histogram
    /// estimate; 0 until the first request).
    pub p50_us: u64,
    /// 99th-percentile per-request service latency, microseconds.
    pub p99_us: u64,
    /// Worst observed per-request service latency, microseconds.
    pub max_us: u64,
    /// Milliseconds since the replica process started serving.
    pub uptime_ms: u64,
}

impl ReplicaStats {
    /// Replica lag: commit sequence numbers the serving model is behind
    /// the newest trainer state the replica has seen on disk.
    pub fn lag(&self) -> u64 {
        self.latest_seq.saturating_sub(self.model_seq)
    }

    fn push(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.tasks.to_le_bytes());
        out.extend_from_slice(&self.dim.to_le_bytes());
        for v in [
            self.model_seq,
            self.latest_seq,
            self.applied_entries,
            self.predictions,
            self.errors,
            self.bootstraps,
            self.hot_swaps,
            self.p50_us,
            self.p99_us,
            self.max_us,
            self.uptime_ms,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn parse(c: &mut Cursor<'_>) -> Result<ReplicaStats, WireError> {
        Ok(ReplicaStats {
            tasks: c.u32()?,
            dim: c.u32()?,
            model_seq: c.u64()?,
            latest_seq: c.u64()?,
            applied_entries: c.u64()?,
            predictions: c.u64()?,
            errors: c.u64()?,
            bootstraps: c.u64()?,
            hot_swaps: c.u64()?,
            p50_us: c.u64()?,
            p99_us: c.u64()?,
            max_us: c.u64()?,
            uptime_ms: c.u64()?,
        })
    }
}

/// A metrics dump answered to [`Request::FetchMetrics`] by **both** the
/// trainer (central server) and the read replica: every named counter,
/// gauge, and histogram in the process's [`obs`](crate::obs) registry
/// at the moment of the request. `amtl top` polls this frame to render
/// its live dashboard; metric names and units are tabulated in
/// `docs/OBSERVABILITY.md`.
///
/// Histograms ship sparse — `(bucket index, count)` pairs for non-empty
/// buckets plus the max/sum accumulators — so an idle registry costs a
/// few bytes per metric, not 65 buckets each.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsReport {
    /// Which process answered: [`MetricsReport::ROLE_TRAINER`],
    /// [`MetricsReport::ROLE_REPLICA`], or [`MetricsReport::ROLE_NODE`].
    pub role: u8,
    /// Milliseconds on the answering process's monotonic metrics clock.
    pub uptime_ms: u64,
    /// Monotonic counters, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// Last-write gauges, name-sorted.
    pub gauges: Vec<(String, u64)>,
    /// Histograms, name-sorted.
    pub hists: Vec<(String, HistSnapshot)>,
    /// Per-node sub-reports fanned in by the trainer: the last
    /// `PushMetrics` snapshot from each worker process, tagged by task
    /// index. One `FetchMetrics` to the trainer therefore sees the whole
    /// training side. Exactly one level deep: a sub-report carries no
    /// `nodes` of its own (the parser rejects nested nesting).
    pub nodes: Vec<(u32, MetricsReport)>,
}

impl MetricsReport {
    /// `role` tag of a training (central) server.
    pub const ROLE_TRAINER: u8 = 0;
    /// `role` tag of a read replica.
    pub const ROLE_REPLICA: u8 = 1;
    /// `role` tag of a worker (task-node) process's piggybacked report.
    pub const ROLE_NODE: u8 = 2;

    /// Assemble a report from a registry snapshot.
    pub fn from_snapshot(role: u8, uptime_ms: u64, snap: crate::obs::MetricsSnapshot) -> MetricsReport {
        MetricsReport {
            role,
            uptime_ms,
            counters: snap.counters,
            gauges: snap.gauges,
            hists: snap.hists,
            nodes: Vec::new(),
        }
    }

    /// Human name of the answering role.
    pub fn role_name(&self) -> &'static str {
        match self.role {
            Self::ROLE_REPLICA => "replica",
            Self::ROLE_NODE => "node",
            _ => "trainer",
        }
    }

    /// Value of the counter named `name`, if present.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Value of the gauge named `name`, if present.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// The histogram named `name`, if present.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(k, _)| k == name).map(|(_, h)| h)
    }

    fn push_name(out: &mut Vec<u8>, name: &str) {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
    }

    fn parse_name(c: &mut Cursor<'_>) -> Result<String, WireError> {
        let n = c.u32()? as usize;
        String::from_utf8(c.take(n)?.to_vec())
            .map_err(|_| WireError::Malformed("metric name is not utf-8"))
    }

    fn push(&self, out: &mut Vec<u8>) {
        out.push(self.role);
        out.extend_from_slice(&self.uptime_ms.to_le_bytes());
        out.extend_from_slice(&(self.counters.len() as u32).to_le_bytes());
        for (name, v) in &self.counters {
            Self::push_name(out, name);
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.gauges.len() as u32).to_le_bytes());
        for (name, v) in &self.gauges {
            Self::push_name(out, name);
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(self.hists.len() as u32).to_le_bytes());
        for (name, h) in &self.hists {
            Self::push_name(out, name);
            out.extend_from_slice(&h.max.to_le_bytes());
            out.extend_from_slice(&h.sum.to_le_bytes());
            let nz: Vec<(usize, u64)> = h.nonzero().collect();
            out.extend_from_slice(&(nz.len() as u32).to_le_bytes());
            for (idx, count) in nz {
                out.push(idx as u8);
                out.extend_from_slice(&count.to_le_bytes());
            }
        }
        out.extend_from_slice(&(self.nodes.len() as u32).to_le_bytes());
        for (t, sub) in &self.nodes {
            out.extend_from_slice(&t.to_le_bytes());
            sub.push(out);
        }
    }

    fn parse(c: &mut Cursor<'_>) -> Result<MetricsReport, WireError> {
        Self::parse_at(c, 0)
    }

    fn parse_at(c: &mut Cursor<'_>, depth: u8) -> Result<MetricsReport, WireError> {
        let role = c.u8()?;
        let uptime_ms = c.u64()?;
        // No count-based preallocation: a corrupted count must run out of
        // payload, not out of memory.
        let mut counters = Vec::new();
        for _ in 0..c.u32()? {
            let name = Self::parse_name(c)?;
            counters.push((name, c.u64()?));
        }
        let mut gauges = Vec::new();
        for _ in 0..c.u32()? {
            let name = Self::parse_name(c)?;
            gauges.push((name, c.u64()?));
        }
        let mut hists = Vec::new();
        for _ in 0..c.u32()? {
            let name = Self::parse_name(c)?;
            let mut snap = HistSnapshot::empty();
            snap.max = c.u64()?;
            snap.sum = c.u64()?;
            for _ in 0..c.u32()? {
                let idx = c.u8()? as usize;
                let count = c.u64()?;
                if idx >= BUCKETS {
                    return Err(WireError::Malformed("histogram bucket index out of range"));
                }
                snap.counts[idx] = snap.counts[idx].wrapping_add(count);
            }
            hists.push((name, snap));
        }
        let mut nodes = Vec::new();
        let node_count = c.u32()?;
        // The fan-in is exactly one level deep: a sub-report claiming
        // sub-reports of its own is malformed, not a recursion.
        if depth > 0 && node_count > 0 {
            return Err(WireError::Malformed("nested node metrics reports"));
        }
        for _ in 0..node_count {
            let t = c.u32()?;
            nodes.push((t, Self::parse_at(c, depth + 1)?));
        }
        Ok(MetricsReport { role, uptime_ms, counters, gauges, hists, nodes })
    }
}

/// One commit inside a [`Request::PushBatch`]: the `PushUpdate` fields,
/// minus nothing — batching changes framing overhead, never semantics.
/// `t` is the **global** task index; the receiving shard validates it
/// against its range and translates to a local column.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchUpdate {
    /// Global task index of the commit.
    pub t: u32,
    /// The node's activation counter (per-update dedup key, exactly as in
    /// `PushUpdate` — a batch is dedup'd element-wise, not atomically).
    pub k: u64,
    /// Cross-process span id, `span_id(t, k)`.
    pub span: u64,
    /// KM relaxation step for this commit.
    pub step: f64,
    /// Forward-step result `u`.
    pub u: Vec<f64>,
}

impl BatchUpdate {
    fn push(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.t.to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&self.span.to_le_bytes());
        out.extend_from_slice(&self.step.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.u.len() as u32).to_le_bytes());
        push_f64s(out, &self.u);
    }

    fn parse(c: &mut Cursor<'_>) -> Result<BatchUpdate, WireError> {
        let t = c.u32()?;
        let k = c.u64()?;
        let span = c.u64()?;
        let step = c.f64()?;
        let n = c.u32()? as usize;
        // Bounds-checked take: a corrupted count runs out of payload, it
        // does not preallocate.
        let bytes = c.take(n.checked_mul(8).ok_or(WireError::Malformed(
            "batch update length overflows",
        ))?)?;
        let u = bytes
            .chunks_exact(8)
            .map(|b| {
                f64::from_bits(u64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ]))
            })
            .collect();
        Ok(BatchUpdate { t, k, span, step, u })
    }
}

/// Client → server messages (the task-node side of Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Retrieve `(Prox_{ηλg}(V̂))_t` — the backward step for task `t`.
    FetchProxCol { t: u32 },
    /// Commit a forward-step result: `v_t ← v_t + step·(u − v_t)`.
    /// `k` is the node's activation counter for this commit — the server
    /// deduplicates on it, turning the at-least-once reconnect-and-resend
    /// of the TCP client into an exactly-once commit (resends of an
    /// already-applied activation are acknowledged without re-applying).
    /// `span` is the commit's cross-process span id
    /// ([`fleet::span_id`](crate::obs::fleet::span_id)`(t, k)`), carried
    /// so the server's trace hops join the worker's without guessing —
    /// the receiving side cross-checks it against `(t, k)`.
    PushUpdate { t: u32, k: u64, span: u64, step: f64, u: Vec<f64> },
    /// Retrieve the run's forward step size η (a run constant).
    FetchEta,
    /// Graceful connection teardown.
    Shutdown,
    /// Join (or rejoin) the run as task node `t`. The reply tells the
    /// node how many of its commits have already been applied, so a
    /// restarted node catches up instead of redoing finished work.
    Register { t: u32 },
    /// Liveness proof for task node `t` (see
    /// [`NodeRegistry`](crate::coordinator::registry::NodeRegistry)).
    Heartbeat { t: u32 },
    /// Polite departure of task node `t` (the run stops waiting for it).
    Leave { t: u32 },
    /// Score the querier's own feature vector `x` against task `t`'s
    /// serving model: `ŷ = ⟨w_t, x⟩`. Answered by read replicas
    /// ([`serve`](crate::serve)), not by the training server.
    Predict { t: u32, x: Vec<f64> },
    /// Retrieve the replica's [`ReplicaStats`] (lag, latency quantiles,
    /// request counters).
    FetchStats,
    /// Retrieve the process's full metrics registry as a
    /// [`MetricsReport`]. Unlike `FetchStats`, this frame is answered by
    /// **both** the trainer and the replica — it is what `amtl top`
    /// polls.
    FetchMetrics,
    /// A worker process's piggybacked registry snapshot (role `NODE`),
    /// pushed on the heartbeat stride so the trainer can fan every
    /// node's metrics into its own [`MetricsReport`]. Fire-and-forget in
    /// spirit: the server acks but never gates training on it.
    PushMetrics { t: u32, report: MetricsReport },
    /// Retrieve the run's [`ShardMap`](crate::shard::ShardMap) — which
    /// shard owns which contiguous task range, and where to dial it.
    /// Answered by every shard (the map is identical fleet-wide), so a
    /// node can bootstrap from any one address it was given.
    FetchShardMap,
    /// Several same-shard commits in one frame (the router coalesces
    /// updates bound for the same shard). Semantically identical to the
    /// same `PushUpdate`s in sequence — element-wise dedup included.
    PushBatch { updates: Vec<BatchUpdate> },
    /// Coordination-round gather: retrieve the shard's **raw** (pre-prox)
    /// slice of `V̂` plus its commit version. Sent by the round
    /// coordinator, never by task nodes.
    FetchSlice,
    /// Coordination-round scatter: install the full-matrix prox result
    /// columns belonging to this shard, tagged with the round number.
    /// `d` is the row count; `w` holds the shard's columns, column-major.
    PushProxSlice { round: u64, d: u32, w: Vec<f64> },
}

/// Server → client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// The requested backward-step column.
    ProxCol(Vec<f64>),
    /// The global version (total KM updates) after the commit landed.
    Pushed { version: u64 },
    /// The run's forward step size η.
    Eta(f64),
    /// Acknowledges a `Shutdown` request. Over a durable server this is
    /// only sent after in-flight WAL writes are fsync'd.
    ShutdownAck,
    /// Membership granted: how many commits task `t` already has applied
    /// (`col_version`) and the node's membership generation (increments
    /// on every re-registration after an eviction or restart).
    Registered { col_version: u64, generation: u64 },
    /// Heartbeat reply; `live = false` means the node was evicted (or was
    /// never registered) and must `Register` again to rejoin.
    HeartbeatAck { live: bool },
    /// Acknowledges a `Leave` request.
    LeaveAck,
    /// The prediction `ŷ` for a `Predict` request, plus the commit
    /// sequence number of the serving model that produced it (so a
    /// client can reason about staleness per answer).
    Prediction { y: f64, model_seq: u64 },
    /// The replica's current [`ReplicaStats`].
    Stats(ReplicaStats),
    /// The process's metrics registry dump (reply to `FetchMetrics`).
    Metrics(MetricsReport),
    /// Acknowledges a `PushMetrics` snapshot.
    MetricsAck,
    /// The run's shard map (reply to `FetchShardMap`).
    ShardMap(crate::shard::ShardMap),
    /// Per-update new global versions for a `PushBatch`, index-aligned
    /// with the request's `updates`.
    PushedBatch { versions: Vec<u64> },
    /// The shard's raw slice of `V̂` (reply to `FetchSlice`): commit
    /// version, row count `d`, and the slice columns, column-major.
    Slice { version: u64, d: u32, w: Vec<f64> },
    /// Acknowledges a `PushProxSlice`, echoing the round number.
    ProxSliceAck { round: u64 },
    /// Request rejected (bad task index, dimension mismatch, …). The
    /// connection stays usable.
    Error(String),
}

impl Request {
    fn opcode(&self) -> u8 {
        match self {
            Request::FetchProxCol { .. } => OP_FETCH_PROX_COL,
            Request::PushUpdate { .. } => OP_PUSH_UPDATE,
            Request::FetchEta => OP_FETCH_ETA,
            Request::Shutdown => OP_SHUTDOWN,
            Request::Register { .. } => OP_REGISTER,
            Request::Heartbeat { .. } => OP_HEARTBEAT,
            Request::Leave { .. } => OP_LEAVE,
            Request::Predict { .. } => OP_PREDICT,
            Request::FetchStats => OP_FETCH_STATS,
            Request::FetchMetrics => OP_FETCH_METRICS,
            Request::PushMetrics { .. } => OP_PUSH_METRICS,
            Request::FetchShardMap => OP_FETCH_SHARD_MAP,
            Request::PushBatch { .. } => OP_PUSH_BATCH,
            Request::FetchSlice => OP_FETCH_SLICE,
            Request::PushProxSlice { .. } => OP_PUSH_PROX_SLICE,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            Request::FetchProxCol { t }
            | Request::Register { t }
            | Request::Heartbeat { t }
            | Request::Leave { t } => t.to_le_bytes().to_vec(),
            Request::PushUpdate { t, k, span, step, u } => {
                let mut out = Vec::with_capacity(28 + u.len() * 8);
                out.extend_from_slice(&t.to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&span.to_le_bytes());
                out.extend_from_slice(&step.to_bits().to_le_bytes());
                push_f64s(&mut out, u);
                out
            }
            Request::Predict { t, x } => {
                let mut out = Vec::with_capacity(4 + x.len() * 8);
                out.extend_from_slice(&t.to_le_bytes());
                push_f64s(&mut out, x);
                out
            }
            Request::PushMetrics { t, report } => {
                let mut out = Vec::new();
                out.extend_from_slice(&t.to_le_bytes());
                report.push(&mut out);
                out
            }
            Request::PushBatch { updates } => {
                let mut out = Vec::new();
                out.extend_from_slice(&(updates.len() as u32).to_le_bytes());
                for up in updates {
                    up.push(&mut out);
                }
                out
            }
            Request::PushProxSlice { round, d, w } => {
                let mut out = Vec::with_capacity(12 + w.len() * 8);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
                push_f64s(&mut out, w);
                out
            }
            Request::FetchEta | Request::Shutdown | Request::FetchStats
            | Request::FetchMetrics | Request::FetchShardMap | Request::FetchSlice => Vec::new(),
        }
    }

    /// Decode from a frame's `(opcode, payload)`.
    pub fn decode(opcode: u8, payload: &[u8]) -> Result<Request, WireError> {
        let mut c = Cursor::new(payload);
        let req = match opcode {
            OP_FETCH_PROX_COL => Request::FetchProxCol { t: c.u32()? },
            OP_PUSH_UPDATE => {
                let t = c.u32()?;
                let k = c.u64()?;
                let span = c.u64()?;
                let step = c.f64()?;
                let u = c.rest_f64s()?;
                Request::PushUpdate { t, k, span, step, u }
            }
            OP_FETCH_ETA => Request::FetchEta,
            OP_SHUTDOWN => Request::Shutdown,
            OP_REGISTER => Request::Register { t: c.u32()? },
            OP_HEARTBEAT => Request::Heartbeat { t: c.u32()? },
            OP_LEAVE => Request::Leave { t: c.u32()? },
            OP_PREDICT => {
                let t = c.u32()?;
                let x = c.rest_f64s()?;
                Request::Predict { t, x }
            }
            OP_FETCH_STATS => Request::FetchStats,
            OP_FETCH_METRICS => Request::FetchMetrics,
            OP_PUSH_METRICS => {
                let t = c.u32()?;
                let report = MetricsReport::parse(&mut c)?;
                Request::PushMetrics { t, report }
            }
            OP_FETCH_SHARD_MAP => Request::FetchShardMap,
            OP_PUSH_BATCH => {
                let mut updates = Vec::new();
                for _ in 0..c.u32()? {
                    updates.push(BatchUpdate::parse(&mut c)?);
                }
                Request::PushBatch { updates }
            }
            OP_FETCH_SLICE => Request::FetchSlice,
            OP_PUSH_PROX_SLICE => {
                let round = c.u64()?;
                let d = c.u32()?;
                let w = c.rest_f64s()?;
                if d == 0 && !w.is_empty() {
                    return Err(WireError::Malformed("prox slice with zero rows"));
                }
                if d != 0 && w.len() % d as usize != 0 {
                    return Err(WireError::Malformed("prox slice not a whole number of columns"));
                }
                Request::PushProxSlice { round, d, w }
            }
            other => return Err(WireError::BadOpcode(other)),
        };
        c.finish()?;
        Ok(req)
    }

    /// Serialize to one complete frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, self.opcode(), &self.payload()).expect("vec write is infallible");
        out
    }

    /// Write one framed request to `w`.
    pub fn write_to(&self, w: &mut impl Write) -> Result<(), WireError> {
        write_frame(w, self.opcode(), &self.payload())
    }

    /// Read one framed request from `r`.
    pub fn read_from(r: &mut impl Read) -> Result<Request, WireError> {
        let (opcode, payload) = read_frame(r)?;
        Request::decode(opcode, &payload)
    }
}

impl Response {
    fn opcode(&self) -> u8 {
        match self {
            Response::ProxCol(_) => OP_PROX_COL,
            Response::Pushed { .. } => OP_PUSHED,
            Response::Eta(_) => OP_ETA,
            Response::ShutdownAck => OP_SHUTDOWN_ACK,
            Response::Registered { .. } => OP_REGISTERED,
            Response::HeartbeatAck { .. } => OP_HEARTBEAT_ACK,
            Response::LeaveAck => OP_LEAVE_ACK,
            Response::Prediction { .. } => OP_PREDICTION,
            Response::Stats(_) => OP_STATS,
            Response::Metrics(_) => OP_METRICS,
            Response::MetricsAck => OP_METRICS_ACK,
            Response::ShardMap(_) => OP_SHARD_MAP,
            Response::PushedBatch { .. } => OP_PUSHED_BATCH,
            Response::Slice { .. } => OP_SLICE,
            Response::ProxSliceAck { .. } => OP_PROX_SLICE_ACK,
            Response::Error(_) => OP_ERROR,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            Response::ProxCol(col) => {
                let mut out = Vec::new();
                push_f64s(&mut out, col);
                out
            }
            Response::Pushed { version } => version.to_le_bytes().to_vec(),
            Response::Eta(eta) => eta.to_bits().to_le_bytes().to_vec(),
            Response::ShutdownAck | Response::LeaveAck | Response::MetricsAck => Vec::new(),
            Response::Registered { col_version, generation } => {
                let mut out = Vec::with_capacity(16);
                out.extend_from_slice(&col_version.to_le_bytes());
                out.extend_from_slice(&generation.to_le_bytes());
                out
            }
            Response::HeartbeatAck { live } => vec![u8::from(*live)],
            Response::Prediction { y, model_seq } => {
                let mut out = Vec::with_capacity(16);
                out.extend_from_slice(&y.to_bits().to_le_bytes());
                out.extend_from_slice(&model_seq.to_le_bytes());
                out
            }
            Response::Stats(stats) => {
                let mut out = Vec::with_capacity(96);
                stats.push(&mut out);
                out
            }
            Response::Metrics(report) => {
                let mut out = Vec::new();
                report.push(&mut out);
                out
            }
            Response::ShardMap(map) => {
                let mut out = Vec::new();
                map.push(&mut out);
                out
            }
            Response::PushedBatch { versions } => {
                let mut out = Vec::with_capacity(4 + versions.len() * 8);
                out.extend_from_slice(&(versions.len() as u32).to_le_bytes());
                for v in versions {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            Response::Slice { version, d, w } => {
                let mut out = Vec::with_capacity(12 + w.len() * 8);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&d.to_le_bytes());
                push_f64s(&mut out, w);
                out
            }
            Response::ProxSliceAck { round } => round.to_le_bytes().to_vec(),
            Response::Error(msg) => msg.as_bytes().to_vec(),
        }
    }

    /// Decode from a frame's `(opcode, payload)`.
    pub fn decode(opcode: u8, payload: &[u8]) -> Result<Response, WireError> {
        let mut c = Cursor::new(payload);
        let resp = match opcode {
            OP_PROX_COL => Response::ProxCol(c.rest_f64s()?),
            OP_PUSHED => Response::Pushed { version: c.u64()? },
            OP_ETA => Response::Eta(c.f64()?),
            OP_SHUTDOWN_ACK => Response::ShutdownAck,
            OP_REGISTERED => Response::Registered { col_version: c.u64()?, generation: c.u64()? },
            OP_HEARTBEAT_ACK => Response::HeartbeatAck {
                live: match c.u8()? {
                    0 => false,
                    1 => true,
                    _ => return Err(WireError::Malformed("heartbeat liveness flag not 0/1")),
                },
            },
            OP_LEAVE_ACK => Response::LeaveAck,
            OP_PREDICTION => Response::Prediction { y: c.f64()?, model_seq: c.u64()? },
            OP_STATS => Response::Stats(ReplicaStats::parse(&mut c)?),
            OP_METRICS => Response::Metrics(MetricsReport::parse(&mut c)?),
            OP_METRICS_ACK => Response::MetricsAck,
            OP_SHARD_MAP => Response::ShardMap(crate::shard::ShardMap::parse(&mut c)?),
            OP_PUSHED_BATCH => {
                let mut versions = Vec::new();
                for _ in 0..c.u32()? {
                    versions.push(c.u64()?);
                }
                Response::PushedBatch { versions }
            }
            OP_SLICE => {
                let version = c.u64()?;
                let d = c.u32()?;
                let w = c.rest_f64s()?;
                if d == 0 && !w.is_empty() {
                    return Err(WireError::Malformed("slice with zero rows"));
                }
                if d != 0 && w.len() % d as usize != 0 {
                    return Err(WireError::Malformed("slice not a whole number of columns"));
                }
                Response::Slice { version, d, w }
            }
            OP_PROX_SLICE_ACK => Response::ProxSliceAck { round: c.u64()? },
            OP_ERROR => {
                let msg = String::from_utf8(payload.to_vec())
                    .map_err(|_| WireError::Malformed("error message is not utf-8"))?;
                return Ok(Response::Error(msg));
            }
            other => return Err(WireError::BadOpcode(other)),
        };
        c.finish()?;
        Ok(resp)
    }

    /// Serialize to one complete frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, self.opcode(), &self.payload()).expect("vec write is infallible");
        out
    }

    /// Write one framed response to `w`.
    pub fn write_to(&self, w: &mut impl Write) -> Result<(), WireError> {
        write_frame(w, self.opcode(), &self.payload())
    }

    /// Read one framed response from `r`.
    pub fn read_from(r: &mut impl Read) -> Result<Response, WireError> {
        let (opcode, payload) = read_frame(r)?;
        Response::decode(opcode, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn roundtrip_request(req: &Request) -> Request {
        let bytes = req.encode();
        let mut r = std::io::Cursor::new(bytes);
        Request::read_from(&mut r).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let bytes = resp.encode();
        let mut r = std::io::Cursor::new(bytes);
        Response::read_from(&mut r).unwrap()
    }

    #[test]
    fn every_request_variant_roundtrips() {
        for req in [
            Request::FetchProxCol { t: 0 },
            Request::FetchProxCol { t: u32::MAX },
            Request::PushUpdate {
                t: 3,
                k: 7,
                span: 0x0003_0000_0000_0007,
                step: 0.9,
                u: vec![1.0, -2.5, f64::MIN_POSITIVE],
            },
            Request::PushUpdate {
                t: 0,
                k: u64::MAX,
                span: 0,
                step: f64::NEG_INFINITY,
                u: vec![],
            },
            Request::FetchEta,
            Request::Shutdown,
            Request::Register { t: 2 },
            Request::Heartbeat { t: u32::MAX },
            Request::Leave { t: 0 },
            Request::Predict { t: 1, x: vec![0.5, -1.5, 2.25] },
            Request::Predict { t: u32::MAX, x: vec![] },
            Request::FetchStats,
            Request::FetchMetrics,
            Request::PushMetrics { t: 2, report: sample_node_report() },
            Request::PushMetrics { t: u32::MAX, report: MetricsReport::default() },
            Request::FetchShardMap,
            Request::PushBatch { updates: sample_batch() },
            Request::PushBatch { updates: vec![] },
            Request::FetchSlice,
            Request::PushProxSlice { round: 3, d: 2, w: vec![1.0, -2.0, 0.5, 4.0] },
            Request::PushProxSlice { round: u64::MAX, d: 0, w: vec![] },
            Request::PushProxSlice { round: 0, d: 7, w: vec![] },
        ] {
            assert_eq!(roundtrip_request(&req), req);
        }
    }

    fn sample_batch() -> Vec<BatchUpdate> {
        vec![
            BatchUpdate { t: 4, k: 9, span: 0x0004_0000_0000_0009, step: 0.5, u: vec![1.0, -1.0] },
            BatchUpdate { t: 5, k: 0, span: 0, step: f64::MIN_POSITIVE, u: vec![] },
            BatchUpdate { t: u32::MAX, k: u64::MAX, span: u64::MAX, step: -3.5, u: vec![2.25] },
        ]
    }

    fn sample_map() -> crate::shard::ShardMap {
        crate::shard::ShardMap::uniform(6, 10, 3)
            .with_addrs(vec!["127.0.0.1:7401".into(), "".into(), "host:9".into()])
            .unwrap()
    }

    fn sample_node_report() -> MetricsReport {
        let h = crate::obs::Histogram::new();
        for v in [5u64, 900, 31_000] {
            h.record(v);
        }
        MetricsReport {
            role: MetricsReport::ROLE_NODE,
            uptime_ms: 4_200,
            counters: vec![("transport.retries".into(), 2)],
            gauges: vec![],
            hists: vec![("node.step_us".into(), h.snapshot())],
            nodes: vec![],
        }
    }

    fn sample_report() -> MetricsReport {
        let h = crate::obs::Histogram::new();
        for v in [0u64, 3, 17, 4096, u64::MAX] {
            h.record(v);
        }
        MetricsReport {
            role: MetricsReport::ROLE_TRAINER,
            uptime_ms: 12_345,
            counters: vec![("server.commits".into(), 9000), ("wal.appends".into(), 9000)],
            gauges: vec![("server.version".into(), 9000)],
            hists: vec![
                ("node.step_us".into(), h.snapshot()),
                ("server.staleness".into(), crate::obs::HistSnapshot::empty()),
            ],
            nodes: vec![(0, sample_node_report()), (3, sample_node_report())],
        }
    }

    fn sample_stats() -> ReplicaStats {
        ReplicaStats {
            tasks: 4,
            dim: 30,
            model_seq: 412,
            latest_seq: 415,
            applied_entries: 412,
            predictions: 10_000,
            errors: 0,
            bootstraps: 1,
            hot_swaps: 2,
            p50_us: 85,
            p99_us: 410,
            max_us: 2_150,
            uptime_ms: 61_200,
        }
    }

    #[test]
    fn replica_stats_lag_semantics() {
        let s = sample_stats();
        assert_eq!(s.lag(), 3);
        // A model ahead of the observed tip (impossible, but the math must
        // not underflow) reads as zero lag.
        let weird = ReplicaStats { model_seq: 9, latest_seq: 3, ..s };
        assert_eq!(weird.lag(), 0);
    }

    #[test]
    fn every_response_variant_roundtrips() {
        for resp in [
            Response::ProxCol(vec![0.0, -0.0, 1e300]),
            Response::ProxCol(vec![]),
            Response::Pushed { version: u64::MAX },
            Response::Eta(1.25e-3),
            Response::ShutdownAck,
            Response::Registered { col_version: 41, generation: 3 },
            Response::Registered { col_version: 0, generation: 0 },
            Response::HeartbeatAck { live: true },
            Response::HeartbeatAck { live: false },
            Response::LeaveAck,
            Response::Prediction { y: -3.75, model_seq: 412 },
            Response::Prediction { y: f64::MAX, model_seq: 0 },
            Response::Stats(sample_stats()),
            Response::Stats(ReplicaStats::default()),
            Response::Metrics(sample_report()),
            Response::Metrics(MetricsReport::default()),
            Response::MetricsAck,
            Response::ShardMap(sample_map()),
            Response::ShardMap(crate::shard::ShardMap::uniform(1, 0, 1)),
            Response::PushedBatch { versions: vec![1, 7, u64::MAX] },
            Response::PushedBatch { versions: vec![] },
            Response::Slice { version: 41, d: 3, w: vec![0.0, -0.0, 1e300, 1.0, 2.0, 3.0] },
            Response::Slice { version: 0, d: 0, w: vec![] },
            Response::ProxSliceAck { round: 12 },
            Response::Error("task index 9 out of range (T=4)".into()),
            Response::Error(String::new()),
        ] {
            assert_eq!(roundtrip_response(&resp), resp);
        }
    }

    #[test]
    fn metrics_report_roundtrip_preserves_statistics() {
        let report = sample_report();
        let back = match roundtrip_response(&Response::Metrics(report.clone())) {
            Response::Metrics(r) => r,
            other => panic!("wrong variant: {other:?}"),
        };
        assert_eq!(back.role_name(), "trainer");
        assert_eq!(back.counter("server.commits"), Some(9000));
        assert_eq!(back.counter("nope"), None);
        assert_eq!(back.gauge("server.version"), Some(9000));
        let h = back.hist("node.step_us").unwrap();
        assert_eq!(h.count(), 5);
        assert_eq!(h.max, u64::MAX);
        assert_eq!(h.quantile(0.5), report.hist("node.step_us").unwrap().quantile(0.5));
        assert!(back.hist("server.staleness").unwrap().is_empty());
        // The fanned-in node rows survive the wire too.
        assert_eq!(back.nodes.len(), 2);
        assert_eq!(back.nodes[1].0, 3);
        assert_eq!(back.nodes[1].1.role_name(), "node");
        assert_eq!(back.nodes[1].1.counter("transport.retries"), Some(2));
        assert_eq!(back.nodes[1].1.hist("node.step_us").unwrap().count(), 3);
    }

    #[test]
    fn metrics_report_rejects_nested_node_reports() {
        // A sub-report is exactly one level deep: hand-encode a report
        // whose node row itself claims a node row.
        let grandchild =
            MetricsReport { role: MetricsReport::ROLE_NODE, ..MetricsReport::default() };
        let child = MetricsReport {
            role: MetricsReport::ROLE_NODE,
            nodes: vec![(1, grandchild)],
            ..MetricsReport::default()
        };
        let root = MetricsReport { nodes: vec![(0, child)], ..MetricsReport::default() };
        let mut payload = Vec::new();
        root.push(&mut payload);
        let mut out = Vec::new();
        write_frame(&mut out, 0x8A, &payload).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Response::decode(op, &payload), Err(WireError::Malformed(_))));
    }

    #[test]
    fn metrics_report_rejects_out_of_range_bucket_index() {
        // Hand-build a Metrics payload whose one histogram claims bucket
        // index 65 (valid indices are 0..=64).
        let mut payload = Vec::new();
        payload.push(1u8); // role
        payload.extend_from_slice(&7u64.to_le_bytes()); // uptime
        payload.extend_from_slice(&0u32.to_le_bytes()); // counters
        payload.extend_from_slice(&0u32.to_le_bytes()); // gauges
        payload.extend_from_slice(&1u32.to_le_bytes()); // hists
        payload.extend_from_slice(&1u32.to_le_bytes()); // name len
        payload.push(b'h');
        payload.extend_from_slice(&9u64.to_le_bytes()); // max
        payload.extend_from_slice(&9u64.to_le_bytes()); // sum
        payload.extend_from_slice(&1u32.to_le_bytes()); // nonzero buckets
        payload.push(65u8); // bucket index out of range
        payload.extend_from_slice(&1u64.to_le_bytes());
        let mut out = Vec::new();
        write_frame(&mut out, 0x8A, &payload).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Response::decode(op, &payload), Err(WireError::Malformed(_))));
    }

    #[test]
    fn prop_arbitrary_push_update_roundtrips() {
        forall(
            "push-update frames encode/decode identically",
            60,
            |g| {
                let n = g.usize_in(0, 400);
                let u = g.normal_vec(n);
                let step = g.f64_in(-10.0, 10.0);
                let t = g.usize_in(0, 1000);
                ((u, step), t)
            },
            |((u, step), t)| {
                let req = Request::PushUpdate {
                    t: *t as u32,
                    k: *t as u64 * 31,
                    span: crate::obs::fleet::span_id(*t, *t as u64 * 31),
                    step: *step,
                    u: u.clone(),
                };
                roundtrip_request(&req) == req
            },
        );
    }

    #[test]
    fn prop_arbitrary_prox_col_roundtrips() {
        forall(
            "prox-col frames encode/decode identically",
            60,
            |g| {
                let n = g.usize_in(0, 400);
                g.normal_vec(n)
            },
            |col| {
                let resp = Response::ProxCol(col.clone());
                roundtrip_response(&resp) == resp
            },
        );
    }

    #[test]
    fn prop_arbitrary_shard_map_roundtrips() {
        forall(
            "shard-map frames encode/decode identically",
            60,
            |g| {
                let t = g.usize_in(0, 64);
                let n = g.usize_in(1, 9);
                let d = g.usize_in(1, 100);
                let with_addrs = g.usize_in(0, 1) == 1;
                (t, n, d, with_addrs)
            },
            |&(t, n, d, with_addrs)| {
                let mut map = crate::shard::ShardMap::uniform(d, t, n);
                if with_addrs {
                    map = map
                        .with_addrs((0..n).map(|i| format!("10.0.0.{i}:7400")).collect())
                        .unwrap();
                }
                let resp = Response::ShardMap(map);
                roundtrip_response(&resp) == resp
            },
        );
    }

    #[test]
    fn prop_arbitrary_push_batch_roundtrips() {
        forall(
            "push-batch frames encode/decode identically",
            60,
            |g| {
                let n = g.usize_in(0, 6);
                (0..n)
                    .map(|i| {
                        let len = g.usize_in(0, 80);
                        BatchUpdate {
                            t: g.usize_in(0, 500) as u32,
                            k: i as u64 * 17,
                            span: crate::obs::fleet::span_id(i, i as u64 * 17),
                            step: g.f64_in(-2.0, 2.0),
                            u: g.normal_vec(len),
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |updates| {
                let req = Request::PushBatch { updates: updates.clone() };
                let versions: Vec<u64> = (0..updates.len() as u64).collect();
                let resp = Response::PushedBatch { versions };
                roundtrip_request(&req) == req && roundtrip_response(&resp) == resp
            },
        );
    }

    #[test]
    fn ragged_slice_and_batch_are_rejected() {
        // A Slice whose f64 count is not a multiple of d is malformed.
        let mut payload = Vec::new();
        payload.extend_from_slice(&5u64.to_le_bytes()); // version
        payload.extend_from_slice(&3u32.to_le_bytes()); // d = 3
        push_f64s(&mut payload, &[1.0, 2.0]); // 2 f64s: not a column
        let mut out = Vec::new();
        write_frame(&mut out, 0x8E, &payload).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Response::decode(op, &payload), Err(WireError::Malformed(_))));
        // A PushBatch whose declared element length overruns the payload
        // errors instead of allocating.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes()); // one update
        payload.extend_from_slice(&0u32.to_le_bytes()); // t
        payload.extend_from_slice(&0u64.to_le_bytes()); // k
        payload.extend_from_slice(&0u64.to_le_bytes()); // span
        payload.extend_from_slice(&0.5f64.to_bits().to_le_bytes()); // step
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // u length: lies
        let mut out = Vec::new();
        write_frame(&mut out, 0x0D, &payload).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Request::decode(op, &payload), Err(WireError::Malformed(_))));
    }

    #[test]
    fn v3_layout_unchanged_by_shard_opcodes() {
        // Read-compat pin: the shard frames are additive, so a pre-shard
        // v3 frame hand-assembled byte-for-byte must still decode. If an
        // existing opcode or field had shifted, this golden layout breaks.
        let mut payload = Vec::new();
        payload.extend_from_slice(&2u32.to_le_bytes()); // t
        payload.extend_from_slice(&5u64.to_le_bytes()); // k
        payload.extend_from_slice(&9u64.to_le_bytes()); // span
        payload.extend_from_slice(&0.5f64.to_bits().to_le_bytes()); // step
        push_f64s(&mut payload, &[1.0, -2.0]);
        let mut frame = Vec::new();
        frame.extend_from_slice(b"AMTL");
        frame.push(3); // version
        frame.push(0x02); // PushUpdate opcode
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        let crc = fnv1a32(&[&frame[4..], &[]]).to_le_bytes();
        frame.extend_from_slice(&crc);
        let got = Request::read_from(&mut std::io::Cursor::new(frame)).unwrap();
        assert_eq!(
            got,
            Request::PushUpdate { t: 2, k: 5, span: 9, step: 0.5, u: vec![1.0, -2.0] }
        );
    }

    #[test]
    fn nan_payloads_roundtrip_bitwise() {
        // PartialEq on NaN is false; compare bit patterns instead.
        let req =
            Request::PushUpdate { t: 1, k: 0, span: 7, step: f64::NAN, u: vec![f64::NAN, 1.0] };
        match roundtrip_request(&req) {
            Request::PushUpdate { t, k: _, span: _, step, u } => {
                assert_eq!(t, 1);
                assert_eq!(step.to_bits(), f64::NAN.to_bits());
                assert_eq!(u[0].to_bits(), f64::NAN.to_bits());
                assert_eq!(u[1], 1.0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_error_never_panic() {
        let frames = [
            Request::PushUpdate { t: 2, k: 5, span: 9, step: 0.5, u: vec![1.0, 2.0, 3.0] }
                .encode(),
            Request::FetchEta.encode(),
            Request::Register { t: 1 }.encode(),
            Request::Predict { t: 0, x: vec![1.0, 2.0] }.encode(),
            Request::PushMetrics { t: 1, report: sample_node_report() }.encode(),
            Request::PushBatch { updates: sample_batch() }.encode(),
            Request::PushProxSlice { round: 2, d: 2, w: vec![1.0, 2.0] }.encode(),
            Response::ProxCol(vec![4.0; 7]).encode(),
            Response::Registered { col_version: 9, generation: 1 }.encode(),
            Response::Stats(sample_stats()).encode(),
            Response::Metrics(sample_report()).encode(),
            Response::ShardMap(sample_map()).encode(),
            Response::PushedBatch { versions: vec![3, 4] }.encode(),
            Response::Slice { version: 9, d: 1, w: vec![0.5, 0.25] }.encode(),
            Response::Error("boom".into()).encode(),
        ];
        for full in &frames {
            for cut in 0..full.len() {
                let mut r = std::io::Cursor::new(&full[..cut]);
                assert!(
                    read_frame(&mut r).is_err(),
                    "prefix of {cut}/{} bytes must not decode",
                    full.len()
                );
            }
        }
    }

    #[test]
    fn corrupted_bytes_error_never_panic() {
        // Any single-byte corruption is caught: magic/version by field
        // checks, everything else by the checksum (which covers the header
        // after the magic and the whole payload).
        let frames = [
            Request::PushUpdate { t: 2, k: 3, span: 11, step: 0.5, u: vec![1.0, -2.0] }.encode(),
            Request::FetchProxCol { t: 7 }.encode(),
            Request::Heartbeat { t: 1 }.encode(),
            Request::Predict { t: 3, x: vec![0.5, 0.25] }.encode(),
            Request::FetchStats.encode(),
            Request::FetchMetrics.encode(),
            Request::PushMetrics { t: 0, report: sample_node_report() }.encode(),
            Request::FetchShardMap.encode(),
            Request::PushBatch { updates: sample_batch() }.encode(),
            Request::FetchSlice.encode(),
            Request::PushProxSlice { round: 1, d: 1, w: vec![2.0] }.encode(),
            Response::ShardMap(sample_map()).encode(),
            Response::PushedBatch { versions: vec![8] }.encode(),
            Response::Slice { version: 3, d: 2, w: vec![1.0, 2.0] }.encode(),
            Response::ProxSliceAck { round: 6 }.encode(),
            Response::Metrics(sample_report()).encode(),
            Response::MetricsAck.encode(),
            Response::Pushed { version: 41 }.encode(),
            Response::Eta(0.125).encode(),
            Response::Prediction { y: 1.5, model_seq: 7 }.encode(),
            Response::Stats(sample_stats()).encode(),
            Response::HeartbeatAck { live: true }.encode(),
        ];
        for full in &frames {
            for pos in 0..full.len() {
                for flip in [0xFFu8, 0x01, 0x80] {
                    let mut bad = full.clone();
                    bad[pos] ^= flip;
                    let mut r = std::io::Cursor::new(bad);
                    // Whichever message family the (possibly corrupted)
                    // opcode lands in, the frame must be rejected: both
                    // decoders have to refuse it.
                    let accepted = match read_frame(&mut r) {
                        Err(_) => false,
                        Ok((op, payload)) => {
                            Request::decode(op, &payload).is_ok()
                                || Response::decode(op, &payload).is_ok()
                        }
                    };
                    assert!(!accepted, "corruption at byte {pos} (xor {flip:#x}) must error");
                }
            }
        }
    }

    #[test]
    fn oversize_length_is_rejected_without_allocating() {
        let mut frame = Request::FetchEta.encode();
        // len field lives at bytes 6..10.
        frame[6..10].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        let mut r = std::io::Cursor::new(frame);
        assert!(matches!(read_frame(&mut r), Err(WireError::Oversize(_))));
    }

    #[test]
    fn wrong_magic_and_version_are_rejected() {
        let mut frame = Request::Shutdown.encode();
        frame[0] = b'X';
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(frame.clone())),
            Err(WireError::BadMagic(_))
        ));
        let mut frame = Request::Shutdown.encode();
        frame[4] = VERSION + 1;
        assert!(matches!(
            read_frame(&mut std::io::Cursor::new(frame)),
            Err(WireError::BadVersion(_))
        ));
    }

    #[test]
    fn unknown_opcode_is_rejected_at_decode() {
        // A frame with a valid checksum but an opcode neither side knows.
        let mut out = Vec::new();
        write_frame(&mut out, 0x7E, &[]).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Request::decode(op, &payload), Err(WireError::BadOpcode(0x7E))));
        assert!(matches!(Response::decode(op, &payload), Err(WireError::BadOpcode(0x7E))));
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        // FetchEta must have an empty payload; 4 stray bytes are malformed.
        let mut out = Vec::new();
        write_frame(&mut out, 0x03, &[0, 0, 0, 0]).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Request::decode(op, &payload), Err(WireError::Malformed(_))));
    }

    #[test]
    fn ragged_f64_vector_is_rejected() {
        // 9 bytes after (t, k, span, step) is not a whole number of f64s.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&4u64.to_le_bytes());
        payload.extend_from_slice(&9u64.to_le_bytes());
        payload.extend_from_slice(&0.5f64.to_bits().to_le_bytes());
        payload.extend_from_slice(&[0u8; 9]);
        let mut out = Vec::new();
        write_frame(&mut out, 0x02, &payload).unwrap();
        let (op, payload) = read_frame(&mut std::io::Cursor::new(out)).unwrap();
        assert!(matches!(Request::decode(op, &payload), Err(WireError::Malformed(_))));
    }
}
