//! The TCP transport: a multi-threaded prox server around
//! [`CentralServer`] and a reconnecting per-node client, speaking the
//! [`wire`](super::wire) protocol over `std::net` sockets.
//!
//! Server side ([`TcpServer::spawn`]): one non-blocking accept loop plus
//! one thread per connection. Each connection is independently framed —
//! a protocol error on one node's socket never corrupts another's. All
//! remote input is validated (task index bounds, update dimension, step
//! finiteness) before it touches the shared state; invalid requests get
//! an `Error` response, never a panic. Connection threads inherit the
//! server's lock sharding: a `PushUpdate` touches only the target column's
//! block and pending slot, and a `FetchProxCol` takes the prox cache's
//! read lock — concurrent nodes contend per-column, never on one
//! server-wide mutex (see [`CentralServer`]'s hot-path notes).
//!
//! Client side ([`TcpClient`]): connect/read/write timeouts, `TCP_NODELAY`
//! (frames are latency-bound request/response pairs, not bulk streams),
//! and bounded reconnect-and-resend on transient failures. Fetches are
//! idempotent; `PushUpdate` resends are deduplicated server-side on the
//! node's activation counter, so commits are exactly-once (see
//! [`Transport::push_update`]).
//!
//! Membership: `Register`/`Heartbeat`/`Leave` frames land in the server's
//! [`NodeRegistry`](crate::coordinator::registry::NodeRegistry) when one
//! is attached, and any fetch/commit from a registered node doubles as a
//! heartbeat. `Shutdown` fsyncs in-flight WAL writes before it is
//! acknowledged.

use super::wire::{BatchUpdate, MetricsReport, Request, Response, WireError};
use super::{RegisterAck, Transport};
use crate::coordinator::metrics::Recorder;
use crate::coordinator::server::CentralServer;
use crate::linalg::Mat;
use crate::obs;
use crate::obs::fleet;
use crate::shard::{ProxShard, ShardMap};
use anyhow::{anyhow, bail, Result};
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Client-side networking knobs.
#[derive(Clone, Copy, Debug)]
pub struct TcpOptions {
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Per-read/per-write socket timeout.
    pub io_timeout: Duration,
    /// Reconnect-and-resend attempts after the first failure.
    pub retries: u32,
    /// Base backoff between attempts (scaled linearly by attempt number).
    pub retry_backoff: Duration,
}

impl Default for TcpOptions {
    fn default() -> TcpOptions {
        TcpOptions {
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(30),
            retries: 3,
            retry_backoff: Duration::from_millis(100),
        }
    }
}

// ---------------------------------------------------------------- server

/// How often blocked server threads wake up to check the shutdown flag.
/// Shared with the serving tier's replica server (`serve::ReplicaServer`).
pub(crate) const POLL: Duration = Duration::from_millis(20);

/// Server-side per-response write timeout: a client that stops reading
/// cannot pin a connection thread (and therefore
/// [`TcpServerHandle::shutdown`], which joins them) forever.
pub(crate) const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// The serving side: accepts task-node connections and answers requests
/// against a shared [`CentralServer`].
pub struct TcpServer;

/// Running server handle. Dropping it (or calling
/// [`TcpServerHandle::shutdown`]) stops the accept loop and joins every
/// connection thread.
pub struct TcpServerHandle {
    addr: SocketAddr,
    stop_flag: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral loopback port)
    /// and serve `server` until the handle is shut down. When `recorder`
    /// is given, every committed update drives trajectory sampling
    /// server-side (used by the standalone `amtl --serve` process; library
    /// sessions record worker-side instead so in-proc and TCP runs sample
    /// identically).
    pub fn spawn(
        addr: &str,
        server: Arc<CentralServer>,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<TcpServerHandle> {
        TcpServer::spawn_impl(addr, server, None, recorder)
    }

    /// Serve one prox shard: like [`TcpServer::spawn`], but requests
    /// address **global** task indices which are translated through the
    /// shard's [`ShardMap`] (tasks owned elsewhere get an `Error`
    /// response naming the owner, so a misrouted client can tell a
    /// stale map from a bad index). Also answers the shard-plane frames:
    /// `FetchShardMap`, `PushBatch`, and the coordination-round
    /// `FetchSlice`/`PushProxSlice` pair.
    pub fn spawn_shard(
        addr: &str,
        shard: Arc<ProxShard>,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<TcpServerHandle> {
        let server = Arc::clone(shard.server());
        TcpServer::spawn_impl(addr, server, Some(shard), recorder)
    }

    fn spawn_impl(
        addr: &str,
        server: Arc<CentralServer>,
        shard: Option<Arc<ProxShard>>,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<TcpServerHandle> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("cannot bind tcp server on {addr}: {e}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop_flag = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let stop = Arc::clone(&stop_flag);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("amtl-tcp-accept".into())
                .spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let server = Arc::clone(&server);
                            let shard = shard.clone();
                            let recorder = recorder.clone();
                            let stop = Arc::clone(&stop);
                            let spawned = std::thread::Builder::new()
                                .name("amtl-tcp-conn".into())
                                .spawn(move || {
                                    serve_conn(
                                        stream,
                                        &server,
                                        shard.as_deref(),
                                        recorder.as_deref(),
                                        &stop,
                                    )
                                });
                            if let Ok(h) = spawned {
                                // Reap finished connection threads so a
                                // long-lived server under reconnect churn
                                // does not accumulate handles unboundedly.
                                let mut conns = conns.lock().unwrap();
                                conns.retain(|c| !c.is_finished());
                                conns.push(h);
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
                        Err(_) => std::thread::sleep(POLL),
                    }
                })?
        };

        Ok(TcpServerHandle { addr: local, stop_flag, accept: Some(accept), conns })
    }
}

impl TcpServerHandle {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake blocked connection threads, join everything.
    /// Idempotent; also invoked on drop.
    pub fn shutdown(&mut self) {
        self.stop_flag.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// `Read` adapter that turns socket read timeouts into shutdown checks:
/// blocked connection threads wake every [`POLL`] interval, look at the
/// stop flag, and otherwise keep waiting. EOF and real errors pass
/// through untouched. Shared with `serve::ReplicaServer`, whose
/// connection loops follow the same discipline.
pub(crate) struct PatientReader<'a> {
    pub(crate) stream: &'a TcpStream,
    pub(crate) stop: &'a AtomicBool,
}

impl Read for PatientReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match (&mut &*self.stream).read(buf) {
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    obs::global().inc("transport.short_reads", 1);
                    if self.stop.load(Ordering::SeqCst) {
                        return Err(std::io::Error::new(
                            ErrorKind::ConnectionAborted,
                            "server shutting down",
                        ));
                    }
                }
                other => return other,
            }
        }
    }
}

/// Algorithmic traffic from a registered node doubles as a heartbeat:
/// any fetch/commit for column `t` refreshes its liveness (and sweeps,
/// so one node's traffic detects another's silence).
fn touch(server: &CentralServer, t: usize) {
    if let Some(reg) = server.registry() {
        let _ = reg.heartbeat(t);
    }
}

/// Translate a request's task index into the column the wrapped server
/// owns: the identity (bounds-checked) for a whole-model server, the
/// shard-map lookup for a shard — tasks owned by a different shard or
/// out of range come back as the error message to send.
fn resolve_t(shard: Option<&ProxShard>, server: &CentralServer, t: usize) -> Result<usize, String> {
    match shard {
        Some(sh) => sh.local(t).map_err(|e| format!("{e:#}")),
        None if t < server.state().t() => Ok(t),
        None => Err(format!("task index {t} out of range (T={})", server.state().t())),
    }
}

/// Validate and apply one commit (shared by `PushUpdate` and
/// `PushBatch`): bounds/ownership, dimension, finiteness, then the
/// exactly-once KM commit on the local column.
fn apply_commit(
    server: &CentralServer,
    shard: Option<&ProxShard>,
    recorder: Option<&Recorder>,
    t: usize,
    k: u64,
    step: f64,
    u: &[f64],
) -> Result<u64, String> {
    let d = server.state().d();
    if u.len() != d {
        return Err(format!("update has dimension {}, expected {d}", u.len()));
    }
    if !step.is_finite() {
        return Err(format!("non-finite km step {step}"));
    }
    if !u.iter().all(|x| x.is_finite()) {
        return Err("update vector contains non-finite values".into());
    }
    let lt = resolve_t(shard, server, t)?;
    touch(server, lt);
    match server.commit_update(lt, k, u, step) {
        Ok(version) => {
            if let Some(rec) = recorder {
                rec.maybe_record(version, || server.state().snapshot());
            }
            Ok(version)
        }
        // Durability failure (e.g. WAL disk error): the update was NOT
        // applied; tell the node so it retries rather than silently
        // losing work.
        Err(e) => Err(format!("commit not durable: {e:#}")),
    }
}

/// One connection's request loop: validate → execute → respond.
fn serve_conn(
    stream: TcpStream,
    server: &CentralServer,
    shard: Option<&ProxShard>,
    recorder: Option<&Recorder>,
    stop: &AtomicBool,
) {
    // Accepted sockets may inherit the listener's non-blocking mode on
    // some platforms; force blocking + a short timeout so PatientReader
    // can poll the stop flag.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = PatientReader { stream: &stream, stop };
    loop {
        let req = match Request::read_from(&mut reader) {
            Ok(req) => req,
            // Client closed, or we are shutting down: silent exit.
            Err(WireError::Io(_)) => return,
            // Framing is corrupt; report once and drop the connection
            // (we cannot resynchronize a byte stream mid-frame).
            Err(e) => {
                let _ = Response::Error(format!("protocol error: {e}")).write_to(&mut &stream);
                return;
            }
        };
        let resp = match req {
            Request::FetchEta => Response::Eta(server.eta()),
            Request::FetchProxCol { t } => match resolve_t(shard, server, t as usize) {
                Ok(lt) => {
                    touch(server, lt);
                    match shard {
                        // Through the shard so coordinated formulations
                        // answer from the round cache, not the raw slice.
                        Some(sh) => match sh.fetch_prox_col(t as usize) {
                            Ok(col) => Response::ProxCol(col),
                            Err(e) => Response::Error(format!("{e:#}")),
                        },
                        None => Response::ProxCol(server.prox_col(lt)),
                    }
                }
                Err(msg) => Response::Error(msg),
            },
            Request::PushUpdate { t, k, span, step, u } => {
                // The span id is derived, not authoritative: a client
                // whose id disagrees with `(t, k)` is logged (it would
                // fragment the cross-process trace) but still applied —
                // tracing must never reject a valid commit.
                if span != fleet::span_id(t as usize, k) {
                    crate::log_debug!(
                        "wire",
                        "push span {span:#018x} != span_id({t}, {k}); tracing by (t, k)"
                    );
                }
                match apply_commit(server, shard, recorder, t as usize, k, step, &u) {
                    Ok(version) => Response::Pushed { version },
                    Err(msg) => Response::Error(msg),
                }
            }
            // Commit several same-destination updates in one exchange.
            // A failure mid-batch aborts the remainder; the partial
            // prefix stays applied, which is safe because the client
            // resends the whole batch and dedup makes each commit
            // exactly-once.
            Request::PushBatch { updates } => {
                let mut versions = Vec::with_capacity(updates.len());
                let mut failed: Option<String> = None;
                for up in &updates {
                    match apply_commit(server, shard, recorder, up.t as usize, up.k, up.step, &up.u)
                    {
                        Ok(version) => versions.push(version),
                        Err(msg) => {
                            failed = Some(msg);
                            break;
                        }
                    }
                }
                match failed {
                    Some(msg) => Response::Error(format!(
                        "batch aborted after {} of {} commits: {msg}",
                        versions.len(),
                        updates.len()
                    )),
                    None => Response::PushedBatch { versions },
                }
            }
            Request::Register { t } => match resolve_t(shard, server, t as usize) {
                Ok(lt) => {
                    let ack = server.register_node(lt);
                    Response::Registered {
                        col_version: ack.col_version,
                        generation: ack.generation,
                    }
                }
                Err(msg) => Response::Error(msg),
            },
            Request::Heartbeat { t } => match resolve_t(shard, server, t as usize) {
                Ok(lt) => {
                    let live = server.registry().map(|r| r.heartbeat(lt)).unwrap_or(true);
                    Response::HeartbeatAck { live }
                }
                Err(msg) => Response::Error(msg),
            },
            Request::Leave { t } => match resolve_t(shard, server, t as usize) {
                Ok(lt) => {
                    if let Some(r) = server.registry() {
                        r.leave(lt);
                    }
                    Response::LeaveAck
                }
                Err(msg) => Response::Error(msg),
            },
            // The routing table: how `amtl --node` finds the shard that
            // owns its column. Whole-model servers answer with an error
            // (clients fall back to direct addressing).
            Request::FetchShardMap => match shard {
                Some(sh) => Response::ShardMap(sh.map().as_ref().clone()),
                None => Response::Error(
                    "this server is not sharded; connect to it directly".into(),
                ),
            },
            // Coordination plane: a consistent raw slice out, a round's
            // full-prox slice back in. A whole-model server answers
            // `FetchSlice` too (its slice is the whole matrix — useful
            // for debugging), but has no round cache to install into.
            Request::FetchSlice => {
                let (version, m) = match shard {
                    Some(sh) => sh.raw_slice(),
                    None => (server.state().version(), server.state().snapshot()),
                };
                Response::Slice { version, d: m.rows() as u32, w: m.data().to_vec() }
            }
            Request::PushProxSlice { round, d, w } => match shard {
                Some(sh) => {
                    let d = d as usize;
                    let cols = if d == 0 { 0 } else { w.len() / d };
                    let mut m = Mat::zeros(d, cols);
                    m.data_mut().copy_from_slice(&w);
                    match sh.install_round(round, m) {
                        Ok(()) => Response::ProxSliceAck { round },
                        Err(e) => Response::Error(format!("{e:#}")),
                    }
                }
                None => Response::Error(
                    "this server is not a shard; there is no round cache to install".into(),
                ),
            },
            // A remote worker exporting its own registry: parked on the
            // server keyed by task index, surfaced as `NODE` rows of the
            // next `FetchMetrics` report.
            Request::PushMetrics { t, report } => {
                server.note_node_metrics(t, report);
                Response::MetricsAck
            }
            // Observability: dump the process-wide metrics registry.
            // Answered by the trainer *and* the replica, so `amtl top`
            // can point at either end of a run. The trainer's report also
            // carries the latest snapshot each remote worker pushed.
            Request::FetchMetrics => {
                let mut report = MetricsReport::from_snapshot(
                    MetricsReport::ROLE_TRAINER,
                    obs::log::uptime_ms(),
                    obs::global().snapshot(),
                );
                report.nodes = server.node_metrics_rows();
                Response::Metrics(report)
            }
            // Serving-tier frames belong to read replicas: the training
            // server refuses them so nobody mistakes it for a predict
            // endpoint (predictions must come from the snapshot+WAL feed,
            // not from a lock on live training state).
            Request::Predict { .. } | Request::FetchStats => Response::Error(
                "this is the training server; predict/stats requests are answered \
                 by a read replica (`amtl --replica <addr> --follow <dir>`)"
                    .into(),
            ),
            Request::Shutdown => {
                // Durability before politeness: fsync in-flight WAL
                // writes, then acknowledge the teardown.
                let _ = server.sync_persist();
                let _ = Response::ShutdownAck.write_to(&mut &stream);
                return;
            }
        };
        if resp.write_to(&mut &stream).is_err() {
            return;
        }
    }
}

// ---------------------------------------------------------------- client

/// A task node's connection to a remote prox server. One client per node;
/// reconnects (with bounded retries and backoff) on transient failures.
pub struct TcpClient {
    addr: SocketAddr,
    opts: TcpOptions,
    stream: Option<TcpStream>,
    eta: f64,
    /// Whether a socket has ever been established (distinguishes the
    /// first connect from a reconnect in the `transport.reconnects`
    /// counter).
    connected_once: bool,
}

impl TcpClient {
    /// Resolve `addr`, connect, and fetch the run's η. Fails fast if the
    /// server is unreachable or speaks a different protocol version.
    pub fn connect(addr: impl ToSocketAddrs, opts: TcpOptions) -> Result<TcpClient> {
        let addr = addr
            .to_socket_addrs()
            .map_err(|e| anyhow!("cannot resolve server address: {e}"))?
            .next()
            .ok_or_else(|| anyhow!("server address resolved to nothing"))?;
        let mut client =
            TcpClient { addr, opts, stream: None, eta: f64::NAN, connected_once: false };
        match client.request(&Request::FetchEta)? {
            Response::Eta(eta) => client.eta = eta,
            other => bail!("handshake expected Eta, got {other:?}"),
        }
        Ok(client)
    }

    fn ensure_connected(&mut self) -> Result<&mut TcpStream> {
        if self.stream.is_none() {
            let stream = TcpStream::connect_timeout(&self.addr, self.opts.connect_timeout)
                .map_err(|e| anyhow!("connect to {}: {e}", self.addr))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.opts.io_timeout))?;
            stream.set_write_timeout(Some(self.opts.io_timeout))?;
            self.stream = Some(stream);
            if self.connected_once {
                obs::global().inc("transport.reconnects", 1);
            }
            self.connected_once = true;
        }
        Ok(self.stream.as_mut().expect("just connected"))
    }

    fn try_request(&mut self, req: &Request) -> Result<Response> {
        let stream = self.ensure_connected()?;
        req.write_to(stream)?;
        Ok(Response::read_from(stream)?)
    }

    /// Send one request, reconnecting and resending on transient
    /// failures. A semantic rejection (`Response::Error`) is terminal —
    /// the server understood us and said no.
    fn request(&mut self, req: &Request) -> Result<Response> {
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                obs::global().inc("transport.retries", 1);
                std::thread::sleep(self.opts.retry_backoff * attempt);
            }
            match self.try_request(req) {
                Ok(Response::Error(msg)) => bail!("server rejected request: {msg}"),
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Connection state is suspect: force a fresh socket.
                    self.stream = None;
                    last_err = Some(e);
                }
            }
        }
        let attempts = self.opts.retries + 1;
        Err(last_err
            .unwrap_or_else(|| anyhow!("request failed"))
            .context(format!("giving up on {} after {attempts} attempts", self.addr)))
    }

    /// Fetch the server's shard map (`FetchShardMap`). Errors against a
    /// whole-model server, which has none.
    pub fn fetch_shard_map(&mut self) -> Result<ShardMap> {
        match self.request(&Request::FetchShardMap)? {
            Response::ShardMap(map) => Ok(map),
            other => bail!("expected ShardMap, got {other:?}"),
        }
    }

    /// Fetch the server's raw model slice (`FetchSlice`): the
    /// coordination round's gather leg. Returns `(version, V_slice)`.
    pub fn fetch_slice(&mut self) -> Result<(u64, Mat)> {
        match self.request(&Request::FetchSlice)? {
            Response::Slice { version, d, w } => {
                let d = d as usize;
                let cols = if d == 0 { 0 } else { w.len() / d };
                let mut m = Mat::zeros(d, cols);
                m.data_mut().copy_from_slice(&w);
                Ok((version, m))
            }
            other => bail!("expected Slice, got {other:?}"),
        }
    }

    /// Install a coordination round's result on a shard
    /// (`PushProxSlice`): the scatter leg. Returns the acknowledged
    /// round number.
    pub fn push_prox_slice(&mut self, round: u64, w: &Mat) -> Result<u64> {
        let req =
            Request::PushProxSlice { round, d: w.rows() as u32, w: w.data().to_vec() };
        match self.request(&req)? {
            Response::ProxSliceAck { round } => Ok(round),
            other => bail!("expected ProxSliceAck, got {other:?}"),
        }
    }
}

impl Transport for TcpClient {
    fn eta(&self) -> f64 {
        self.eta
    }

    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>> {
        match self.request(&Request::FetchProxCol { t: t as u32 })? {
            Response::ProxCol(col) => Ok(col),
            other => bail!("expected ProxCol, got {other:?}"),
        }
    }

    fn push_update(&mut self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        // The span id is derived here rather than taken as a parameter, so
        // a frame's carried span always agrees with its `(t, k)` identity.
        let span = fleet::span_id(t, k);
        match self.request(&Request::PushUpdate { t: t as u32, k, span, step, u: u.to_vec() })? {
            Response::Pushed { version } => Ok(version),
            other => bail!("expected Pushed, got {other:?}"),
        }
    }

    fn push_batch(&mut self, updates: &[BatchUpdate]) -> Result<Vec<u64>> {
        if updates.is_empty() {
            return Ok(Vec::new());
        }
        match self.request(&Request::PushBatch { updates: updates.to_vec() })? {
            Response::PushedBatch { versions } => {
                if versions.len() != updates.len() {
                    bail!(
                        "batch ack carries {} versions for {} updates",
                        versions.len(),
                        updates.len()
                    );
                }
                Ok(versions)
            }
            other => bail!("expected PushedBatch, got {other:?}"),
        }
    }

    fn register(&mut self, t: usize) -> Result<RegisterAck> {
        match self.request(&Request::Register { t: t as u32 })? {
            Response::Registered { col_version, generation } => {
                Ok(RegisterAck { col_version, generation })
            }
            other => bail!("expected Registered, got {other:?}"),
        }
    }

    fn heartbeat(&mut self, t: usize) -> Result<bool> {
        match self.request(&Request::Heartbeat { t: t as u32 })? {
            Response::HeartbeatAck { live } => Ok(live),
            other => bail!("expected HeartbeatAck, got {other:?}"),
        }
    }

    fn leave(&mut self, t: usize) -> Result<()> {
        match self.request(&Request::Leave { t: t as u32 })? {
            Response::LeaveAck => Ok(()),
            other => bail!("expected LeaveAck, got {other:?}"),
        }
    }

    fn push_metrics(&mut self, t: usize, report: MetricsReport) -> Result<()> {
        match self.request(&Request::PushMetrics { t: t as u32, report })? {
            Response::MetricsAck => Ok(()),
            other => bail!("expected MetricsAck, got {other:?}"),
        }
    }

    fn close(&mut self) -> Result<()> {
        // Best-effort polite teardown; a vanished server is not an error.
        if self.stream.is_some() {
            let _ = self.try_request(&Request::Shutdown);
            self.stream = None;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::optim::prox::{Regularizer, RegularizerKind};
    use crate::util::Rng;

    fn server(d: usize, t: usize) -> Arc<CentralServer> {
        let state = Arc::new(SharedState::zeros(d, t));
        Arc::new(CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.2), 0.125))
    }

    fn quick_opts() -> TcpOptions {
        TcpOptions {
            connect_timeout: Duration::from_millis(500),
            io_timeout: Duration::from_millis(500),
            retries: 1,
            retry_backoff: Duration::from_millis(10),
        }
    }

    #[test]
    fn loopback_roundtrip_fetch_push_eta() {
        let srv = server(6, 3);
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();
        assert_eq!(client.eta(), 0.125, "handshake fetched eta");

        let mut rng = Rng::new(910);
        let u = rng.normal_vec(6);
        let version = client.push_update(2, 0, 0.5, &u).unwrap();
        assert_eq!(version, 1);
        assert_eq!(srv.state().col_version(2), 1);

        // The fetched column equals the server's own prox column.
        let got = client.fetch_prox_col(2).unwrap();
        assert_eq!(got, srv.prox_col(2));

        client.close().unwrap();
        handle.shutdown();
    }

    #[test]
    fn invalid_requests_get_error_responses_not_panics() {
        let srv = server(4, 2);
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();

        let err = client.fetch_prox_col(9).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        let err = client.push_update(0, 0, 0.5, &[1.0; 3]).unwrap_err();
        assert!(format!("{err:#}").contains("dimension"), "{err:#}");
        let err = client.push_update(0, 0, f64::NAN, &[1.0; 4]).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        let err = client.push_update(0, 0, 0.5, &[1.0, f64::INFINITY, 0.0, 0.0]).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");

        // The connection survives rejections: a valid request still works.
        assert_eq!(client.push_update(0, 0, 1.0, &[1.0; 4]).unwrap(), 1);
        assert_eq!(srv.state().read_col(0), vec![1.0; 4]);
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients_all_commit() {
        let srv = server(5, 4);
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let addr = handle.addr();
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut client = TcpClient::connect(addr, quick_opts()).unwrap();
                    for k in 0..25 {
                        let col = client.fetch_prox_col(t).unwrap();
                        assert_eq!(col.len(), 5);
                        client.push_update(t, k, 0.5, &[1.0; 5]).unwrap();
                    }
                    client.close().unwrap();
                });
            }
        });
        assert_eq!(srv.state().version(), 100);
        for t in 0..4 {
            assert_eq!(srv.state().col_version(t), 25);
        }
        handle.shutdown();
    }

    #[test]
    fn resent_push_updates_are_exactly_once() {
        // The at-least-once wire retry must not double-apply: resending
        // the same activation acks without moving the state.
        let srv = server(3, 1);
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();
        assert_eq!(client.push_update(0, 0, 0.5, &[2.0, 2.0, 2.0]).unwrap(), 1);
        let col = srv.state().read_col(0);
        assert_eq!(client.push_update(0, 0, 0.5, &[2.0, 2.0, 2.0]).unwrap(), 1);
        assert_eq!(srv.state().read_col(0), col, "resend must not re-apply");
        assert_eq!(client.push_update(0, 1, 0.5, &[2.0, 2.0, 2.0]).unwrap(), 2);
        handle.shutdown();
    }

    #[test]
    fn membership_frames_roundtrip_against_a_registry() {
        let state = Arc::new(SharedState::zeros(4, 2));
        let registry = Arc::new(crate::coordinator::registry::NodeRegistry::new(
            2,
            Duration::from_millis(150),
        ));
        let srv = Arc::new(
            CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.2), 0.125)
                .with_registry(Arc::clone(&registry)),
        );
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();

        // Heartbeat before registering: not a member.
        assert!(!client.heartbeat(0).unwrap());
        let ack = client.register(0).unwrap();
        assert_eq!(ack, RegisterAck { col_version: 0, generation: 1 });
        assert!(client.heartbeat(0).unwrap());

        // Commits advance the catch-up horizon a re-registration reports.
        client.push_update(0, 0, 1.0, &[1.0; 4]).unwrap();
        client.push_update(0, 1, 1.0, &[1.0; 4]).unwrap();
        let ack = client.register(0).unwrap();
        assert_eq!(ack.col_version, 2);
        assert_eq!(ack.generation, 2, "re-registration bumps the generation");

        // Node 0 goes silent while node 1 keeps heartbeating: node 1's
        // traffic performs the sweeps, node 0 is evicted on the timeout
        // and told to rejoin on its next heartbeat.
        client.register(1).unwrap();
        let silent_since = std::time::Instant::now();
        while silent_since.elapsed() < Duration::from_millis(400) && !registry.is_evicted(0) {
            client.heartbeat(1).unwrap();
            std::thread::sleep(Duration::from_millis(15));
        }
        assert!(registry.is_evicted(0), "silent node evicted by peer traffic");
        assert!(!client.heartbeat(0).unwrap());
        client.leave(1).unwrap();
        assert_eq!(
            registry.status(1),
            crate::coordinator::registry::NodeStatus::Left
        );
        handle.shutdown();
    }

    #[test]
    fn requests_after_server_shutdown_error_in_bounded_time() {
        let srv = server(3, 1);
        let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();
        handle.shutdown();
        let start = std::time::Instant::now();
        let err = client.fetch_prox_col(0).unwrap_err();
        assert!(format!("{err:#}").contains("giving up"), "{err:#}");
        assert!(start.elapsed() < Duration::from_secs(5), "retry loop must be bounded");
    }

    #[test]
    fn shard_server_translates_global_indices() {
        use crate::optim::prox::L1Prox;
        // uniform(4, 6, 2): shard 0 owns tasks 0..3, shard 1 owns 3..6.
        let map = Arc::new(ShardMap::uniform(4, 6, 2));
        let shard =
            Arc::new(ProxShard::create(Arc::clone(&map), 1, &L1Prox::new(0.1), 0.25, None).unwrap());
        let mut handle = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&shard), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();
        assert_eq!(client.eta(), 0.25, "handshake works against a shard");

        // Global task 4 is the shard's local column 1.
        assert_eq!(client.push_update(4, 0, 1.0, &[1.0; 4]).unwrap(), 1);
        assert_eq!(shard.server().state().read_col(1), vec![1.0; 4]);
        assert_eq!(client.fetch_prox_col(4).unwrap(), shard.fetch_prox_col(4).unwrap());

        // Tasks owned elsewhere and out of range are rejected, not misrouted.
        let err = client.fetch_prox_col(0).unwrap_err();
        assert!(format!("{err:#}").contains("owned by shard 0"), "{err:#}");
        let err = client.push_update(9, 0, 1.0, &[1.0; 4]).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");

        // The routing table comes back over the wire intact.
        assert_eq!(&client.fetch_shard_map().unwrap(), map.as_ref());
        handle.shutdown();
    }

    #[test]
    fn shard_batch_and_slice_frames_roundtrip() {
        use crate::optim::prox::L1Prox;
        let map = Arc::new(ShardMap::uniform(3, 4, 2));
        let shard =
            Arc::new(ProxShard::create(map, 0, &L1Prox::new(0.0), 0.5, None).unwrap());
        let mut handle = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&shard), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();

        let mk = |t: usize, k: u64, x: f64| BatchUpdate {
            t: t as u32,
            k,
            span: fleet::span_id(t, k),
            step: 1.0,
            u: vec![x; 3],
        };
        assert_eq!(client.push_batch(&[mk(0, 0, 1.0), mk(1, 0, 2.0)]).unwrap(), vec![1, 2]);
        assert_eq!(shard.server().state().read_col(0), vec![1.0; 3]);
        assert_eq!(shard.server().state().read_col(1), vec![2.0; 3]);

        // A foreign task aborts the batch with an error (prefix stays
        // applied; dedup makes the client's resend exactly-once).
        let err = client.push_batch(&[mk(0, 1, 3.0), mk(2, 0, 4.0)]).unwrap_err();
        assert!(format!("{err:#}").contains("batch aborted after 1 of 2"), "{err:#}");

        // Gather leg: the raw slice with its version.
        let (version, slice) = client.fetch_slice().unwrap();
        assert_eq!(version, 3);
        assert_eq!((slice.rows(), slice.cols()), (3, 2));
        assert_eq!(slice.col(0), &[3.0; 3][..]);

        // Scatter leg: a separable shard has no round cache to fill.
        let err = client.push_prox_slice(1, &slice).unwrap_err();
        assert!(format!("{err:#}").contains("separable"), "{err:#}");
        handle.shutdown();
    }

    #[test]
    fn coordinated_shard_serves_installed_round_over_wire() {
        use crate::optim::coupling::MeanProx;
        let map = Arc::new(ShardMap::uniform(2, 4, 2));
        let shard =
            Arc::new(ProxShard::create(map, 1, &MeanProx::new(0.3), 0.5, None).unwrap());
        let mut handle = TcpServer::spawn_shard("127.0.0.1:0", Arc::clone(&shard), None).unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();

        let mut round = Mat::zeros(2, 2);
        round.set_col(0, &[1.5, -2.5]);
        round.set_col(1, &[0.25, 4.0]);
        assert_eq!(client.push_prox_slice(7, &round).unwrap(), 7);
        assert_eq!(shard.round(), 7);
        // Fetches now answer from the installed cache (global task 3 =
        // local column 1 of the slice).
        assert_eq!(client.fetch_prox_col(3).unwrap(), vec![0.25, 4.0]);
        handle.shutdown();
    }

    #[test]
    fn server_side_recorder_samples_commits() {
        let srv = server(2, 1);
        let recorder = Arc::new(Recorder::new(1));
        let mut handle =
            TcpServer::spawn("127.0.0.1:0", Arc::clone(&srv), Some(Arc::clone(&recorder)))
                .unwrap();
        let mut client = TcpClient::connect(handle.addr(), quick_opts()).unwrap();
        for k in 0..5 {
            client.push_update(0, k, 1.0, &[2.0, 2.0]).unwrap();
        }
        client.close().unwrap();
        handle.shutdown();
        let recorder = Arc::try_unwrap(recorder).ok().expect("all clones dropped");
        assert_eq!(recorder.into_points().len(), 5);
    }
}
