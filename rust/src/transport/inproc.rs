//! The shared-memory transport: direct calls into the central server.
//!
//! This is the pre-transport data path, preserved exactly: `fetch` is
//! [`CentralServer::prox_col`], `push` is [`CentralServer::commit_update`]
//! (the same KM-relaxation + online-SVD bookkeeping the worker loop used
//! to inline). No serialization, no copies beyond the column hand-off —
//! and therefore bit-identical behavior to the coordinator before the
//! transport layer existed.

use super::Transport;
use crate::coordinator::server::CentralServer;
use anyhow::Result;
use std::sync::Arc;

/// Shared-memory edge: every "message" is a method call on the server.
pub struct InProc {
    server: Arc<CentralServer>,
}

impl InProc {
    /// A transport bound to `server`.
    pub fn new(server: Arc<CentralServer>) -> InProc {
        InProc { server }
    }
}

impl Transport for InProc {
    fn eta(&self) -> f64 {
        self.server.eta()
    }

    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>> {
        Ok(self.server.prox_col(t))
    }

    fn push_update(&mut self, t: usize, step: f64, u: &[f64]) -> Result<u64> {
        Ok(self.server.commit_update(t, u, step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::optim::prox::{Regularizer, RegularizerKind};
    use crate::util::Rng;

    fn server(d: usize, t: usize) -> Arc<CentralServer> {
        let state = Arc::new(SharedState::zeros(d, t));
        Arc::new(CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.2), 0.1))
    }

    #[test]
    fn inproc_matches_direct_server_calls() {
        let srv = server(5, 3);
        let mut tr = InProc::new(Arc::clone(&srv));
        assert_eq!(tr.eta(), srv.eta());
        let mut rng = Rng::new(900);
        let u = rng.normal_vec(5);
        let v1 = tr.push_update(1, 0.7, &u).unwrap();
        assert_eq!(v1, 1);
        assert_eq!(srv.state().col_version(1), 1);
        // The fetched column is exactly the server's prox column.
        let got = tr.fetch_prox_col(1).unwrap();
        assert_eq!(got, srv.prox_col(1));
        // And push applied the KM relaxation: v = 0 + 0.7 (u - 0).
        let col = srv.state().read_col(1);
        for (c, ui) in col.iter().zip(&u) {
            assert!((c - 0.7 * ui).abs() < 1e-15);
        }
    }

    #[test]
    fn close_is_a_noop() {
        let mut tr = InProc::new(server(2, 1));
        assert!(tr.close().is_ok());
    }
}
