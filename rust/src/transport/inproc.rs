//! The shared-memory transport: direct calls into the central server.
//!
//! This is the pre-transport data path, preserved exactly: `fetch` is
//! [`CentralServer::prox_col`], `push` is [`CentralServer::commit_update`]
//! (the same KM-relaxation + online-SVD bookkeeping the worker loop used
//! to inline). No serialization, no copies beyond the column hand-off —
//! and therefore bit-identical behavior to the coordinator before the
//! transport layer existed.

use super::{RegisterAck, Transport};
use crate::coordinator::server::CentralServer;
use anyhow::Result;
use std::sync::Arc;

/// Shared-memory edge: every "message" is a method call on the server.
pub struct InProc {
    server: Arc<CentralServer>,
}

impl InProc {
    /// A transport bound to `server`.
    pub fn new(server: Arc<CentralServer>) -> InProc {
        InProc { server }
    }

    /// Algorithmic traffic doubles as a heartbeat, exactly like the TCP
    /// server does for remote nodes: an active node is a live node.
    fn touch(&self, t: usize) {
        if let Some(r) = self.server.registry() {
            let _ = r.heartbeat(t);
        }
    }
}

impl Transport for InProc {
    fn eta(&self) -> f64 {
        self.server.eta()
    }

    fn fetch_prox_col(&mut self, t: usize) -> Result<Vec<f64>> {
        self.touch(t);
        Ok(self.server.prox_col(t))
    }

    fn push_update(&mut self, t: usize, k: u64, step: f64, u: &[f64]) -> Result<u64> {
        self.touch(t);
        self.server.commit_update(t, k, u, step)
    }

    fn register(&mut self, t: usize) -> Result<RegisterAck> {
        Ok(self.server.register_node(t))
    }

    fn heartbeat(&mut self, t: usize) -> Result<bool> {
        Ok(self.server.registry().map(|r| r.heartbeat(t)).unwrap_or(true))
    }

    fn leave(&mut self, t: usize) -> Result<()> {
        if let Some(r) = self.server.registry() {
            r.leave(t);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::SharedState;
    use crate::optim::prox::{Regularizer, RegularizerKind};
    use crate::util::Rng;

    fn server(d: usize, t: usize) -> Arc<CentralServer> {
        let state = Arc::new(SharedState::zeros(d, t));
        Arc::new(CentralServer::new(state, Regularizer::new(RegularizerKind::L21, 0.2), 0.1))
    }

    #[test]
    fn inproc_matches_direct_server_calls() {
        let srv = server(5, 3);
        let mut tr = InProc::new(Arc::clone(&srv));
        assert_eq!(tr.eta(), srv.eta());
        let mut rng = Rng::new(900);
        let u = rng.normal_vec(5);
        let v1 = tr.push_update(1, 0, 0.7, &u).unwrap();
        assert_eq!(v1, 1);
        assert_eq!(srv.state().col_version(1), 1);
        // Membership defaults without a registry: catch-up info still real.
        let ack = tr.register(1).unwrap();
        assert_eq!(ack, super::super::RegisterAck { col_version: 1, generation: 0 });
        assert!(tr.heartbeat(1).unwrap());
        tr.leave(1).unwrap();
        // The fetched column is exactly the server's prox column.
        let got = tr.fetch_prox_col(1).unwrap();
        assert_eq!(got, srv.prox_col(1));
        // And push applied the KM relaxation: v = 0 + 0.7 (u - 0).
        let col = srv.state().read_col(1);
        for (c, ui) in col.iter().zip(&u) {
            assert!((c - 0.7 * ui).abs() < 1e-15);
        }
    }

    #[test]
    fn close_is_a_noop() {
        let mut tr = InProc::new(server(2, 1));
        assert!(tr.close().is_ok());
    }
}
