//! `amtl` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `train`     — run one optimization under a chosen update schedule
//!                 (`--method amtl|smtl|semisync`).
//! * `compare`   — AMTL vs SMTL side by side under one network setting.
//! * `datasets`  — print the Table-II style description of the built-in
//!                 dataset simulators.
//! * `artifacts` — verify the AOT artifact manifest loads and list buckets.
//!
//! Examples:
//!
//! ```text
//! amtl train --dataset school-small --reg nuclear --lambda 0.5 --iters 20
//! amtl train --tasks 10 --n 100 --dim 50 --offset 5 --engine pjrt
//! amtl train --method semisync --staleness 4 --tasks 8 --offset 5
//! amtl compare --tasks 5 --offset 5 --iters 10
//! ```

use amtl::config::Opts;
use amtl::coordinator::{
    Async, MtlProblem, Schedule, SemiSync, Session, Synchronized,
};
use amtl::data::{public, synthetic, MultiTaskDataset};
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::{ComputePool, Engine, PoolConfig};
use amtl::util::Rng;
use anyhow::{anyhow, bail, Result};
use std::time::Duration;

fn main() {
    let opts = match Opts::from_env() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&opts) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(opts: &Opts) -> Result<()> {
    let cmd = opts.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(opts),
        "compare" => cmd_compare(opts),
        "datasets" => cmd_datasets(opts),
        "artifacts" => cmd_artifacts(opts),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (see `amtl help`)"),
    }
}

const HELP: &str = "\
amtl — Asynchronous Multi-Task Learning (Baytas et al., 2016)

USAGE: amtl <command> [options]

COMMANDS:
  train       run one optimization (default method: amtl)
  compare     run AMTL and SMTL under identical network settings
  datasets    describe the built-in dataset simulators
  artifacts   validate the AOT artifact manifest
  help        this text

DATA OPTIONS (synthetic unless --dataset is given):
  --dataset <school|mnist|mtfl|school-small>   simulated public dataset
  --tasks N      number of synthetic tasks          [5]
  --n N          samples per synthetic task         [100]
  --dim D        feature dimension                  [50]
  --rank R       planted shared-subspace rank       [3]
  --noise S      label noise sigma                  [0.1]

PROBLEM OPTIONS:
  --reg <nuclear|l21|l1|elasticnet|none>           [nuclear]
  --lambda L     regularization strength            [0.5]
  --eta-scale S  eta = S * 2/L_max, S in (0,1)      [0.5]

RUN OPTIONS:
  --method <amtl|smtl|semisync>                    [amtl]
                 amtl     = asynchronous (Algorithm 1, no barrier)
                 smtl     = synchronized baseline (barrier per round)
                 semisync = bounded staleness (see --staleness)
  --staleness B  semisync: max activations ahead of the slowest node [4]
  --iters K      activations per task node          [10]
  --offset U     delay offset in paper units        [0]
  --time-scale MS  wall-clock ms per paper unit     [100]
  --eta-k V      KM relaxation step                 [0.5]
  --dynamic-step enable Eq. III.6 dynamic step
  --online-svd   incremental nuclear prox (ablation)
  --sgd FRAC     stochastic forward steps with this minibatch fraction
  --prox-every K server re-prox stride              [1]
  --engine <pjrt|native>                           [native]
  --executors N  PJRT executor threads              [2]
  --artifacts-dir PATH                             [artifacts]
  --record-every K  trajectory sampling stride      [max(1, T*iters/50)]
  --seed S                                         [7]
";

/// Assemble the dataset from CLI options.
fn build_dataset(opts: &Opts, rng: &mut Rng) -> Result<MultiTaskDataset> {
    if let Some(name) = opts.get("dataset") {
        return public::by_name(name, rng)
            .ok_or_else(|| anyhow!("unknown dataset '{name}' (school|mnist|mtfl|school-small)"));
    }
    let t = opts.get_usize("tasks", 5)?;
    let n = opts.get_usize("n", 100)?;
    let d = opts.get_usize("dim", 50)?;
    let rank = opts.get_usize("rank", 3)?;
    let noise = opts.get_f64("noise", 0.1)?;
    Ok(synthetic::lowrank_regression(&vec![n; t], d, rank.min(d), noise, rng))
}

fn build_problem(opts: &Opts, rng: &mut Rng) -> Result<MtlProblem> {
    let ds = build_dataset(opts, rng)?;
    let reg = RegularizerKind::parse(&opts.get_or("reg", "nuclear"))
        .ok_or_else(|| anyhow!("bad --reg"))?;
    let lambda = opts.get_f64("lambda", 0.5)?;
    let eta_scale = opts.get_f64("eta-scale", 0.5)?;
    Ok(MtlProblem::new(ds, reg, lambda, eta_scale, rng))
}

struct RunOpts {
    iters: usize,
    sgd_fraction: Option<f64>,
    offset: f64,
    time_scale: Duration,
    eta_k: f64,
    dynamic: bool,
    online_svd: bool,
    prox_every: u64,
    engine: Engine,
    executors: usize,
    artifacts_dir: String,
    record_every: u64,
    seed: u64,
}

fn run_opts(opts: &Opts, t: usize) -> Result<RunOpts> {
    let iters = opts.get_usize("iters", 10)?;
    let default_record = ((t * iters) as u64 / 50).max(1);
    let sgd = opts.get_f64("sgd", 0.0)?;
    Ok(RunOpts {
        iters,
        sgd_fraction: if sgd > 0.0 { Some(sgd) } else { None },
        offset: opts.get_f64("offset", 0.0)?,
        time_scale: Duration::from_millis(opts.get_u64("time-scale", 100)?),
        eta_k: opts.get_f64("eta-k", 0.5)?,
        dynamic: opts.flag("dynamic-step"),
        online_svd: opts.flag("online-svd"),
        prox_every: opts.get_u64("prox-every", 1)?,
        engine: Engine::parse(&opts.get_or("engine", "native"))
            .ok_or_else(|| anyhow!("bad --engine"))?,
        executors: opts.get_usize("executors", 2)?,
        artifacts_dir: opts.get_or("artifacts-dir", "artifacts"),
        record_every: opts.get_u64("record-every", default_record)?,
        seed: opts.get_u64("seed", 7)?,
    })
}

/// Configure a [`Session`] builder from the parsed run options (the one
/// wiring path every method shares).
fn session<'p>(
    problem: &'p MtlProblem,
    pool: Option<&'p ComputePool>,
    ro: &RunOpts,
    schedule: Box<dyn Schedule>,
) -> amtl::coordinator::SessionBuilder<'p> {
    Session::builder(problem)
        .engine(ro.engine)
        .pool(pool)
        .iters_per_node(ro.iters)
        .sgd_fraction(ro.sgd_fraction)
        .time_scale(ro.time_scale)
        .eta_k(ro.eta_k)
        .dynamic_step(ro.dynamic)
        .prox_every(ro.prox_every)
        .record_every(ro.record_every)
        .online_svd(ro.online_svd)
        .seed(ro.seed)
        .paper_offset(ro.offset)
        .schedule_box(schedule)
}

/// Resolve `--method` (+ `--staleness`) into a schedule.
fn parse_schedule(opts: &Opts) -> Result<Box<dyn Schedule>> {
    let method = opts
        .get_one_of("method", &["amtl", "smtl", "semisync"], "amtl")
        .map_err(|e| anyhow!("{e}"))?;
    let staleness_given = opts.get("staleness").is_some();
    let staleness = opts.get_u64("staleness", 4)?;
    if staleness_given && method != "semisync" {
        bail!("--staleness only applies to --method semisync (got --method {method})");
    }
    Ok(match method.as_str() {
        "amtl" => Box::new(Async),
        "smtl" => Box::new(Synchronized),
        "semisync" => Box::new(SemiSync { staleness_bound: staleness }),
        _ => unreachable!("get_one_of validated the method"),
    })
}

fn make_pool(ro: &RunOpts) -> Result<Option<ComputePool>> {
    if ro.engine == Engine::Pjrt {
        Ok(Some(ComputePool::new(PoolConfig {
            executors: ro.executors,
            artifacts_dir: ro.artifacts_dir.clone().into(),
        })?))
    } else {
        Ok(None)
    }
}

fn cmd_train(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let schedule = parse_schedule(opts)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    println!("dataset: {}", problem.dataset.describe());
    println!(
        "problem: reg={} lambda={} eta={:.3e} L={:.3e}",
        problem.reg_kind.name(),
        problem.lambda,
        problem.eta,
        problem.l_max
    );
    let pool = make_pool(&ro)?;
    let result = session(&problem, pool.as_ref(), &ro, schedule).build()?.run()?;

    println!("{}", result.summary());
    let objs = result.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));
    for (secs, ver, obj) in &objs {
        println!("  t={secs:8.3}s  k={ver:6}  F={obj:.6}");
    }
    println!(
        "final objective: {:.6}  (train RMSE {:.4})",
        problem.objective(&result.w_final),
        problem.train_rmse(&result.w_final)
    );
    Ok(())
}

fn cmd_compare(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    println!("dataset: {}", problem.dataset.describe());
    let pool = make_pool(&ro)?;

    let amtl_res = session(&problem, pool.as_ref(), &ro, Box::new(Async))
        .build()?
        .run()?;
    let smtl_res = session(&problem, pool.as_ref(), &ro, Box::new(Synchronized))
        .build()?
        .run()?;

    println!("{}", amtl_res.summary());
    println!("{}", smtl_res.summary());
    println!(
        "AMTL objective {:.6} | SMTL objective {:.6} | speedup {:.2}x",
        problem.objective(&amtl_res.w_final),
        problem.objective(&smtl_res.w_final),
        smtl_res.wall_time.as_secs_f64() / amtl_res.wall_time.as_secs_f64().max(1e-9),
    );
    Ok(())
}

fn cmd_datasets(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    println!("Table II — simulated public datasets:");
    for name in ["school", "mnist", "mtfl"] {
        let ds = public::by_name(name, &mut rng).unwrap();
        println!("  {}", ds.describe());
    }
    Ok(())
}

fn cmd_artifacts(opts: &Opts) -> Result<()> {
    let dir = opts.get_or("artifacts-dir", "artifacts");
    let m = amtl::runtime::Manifest::load(std::path::Path::new(&dir))?;
    println!(
        "manifest OK: {} artifacts in {dir} (tile_n={})",
        m.len(),
        m.tile_n
    );
    for key in m.keys() {
        println!("  {key}");
    }
    Ok(())
}
