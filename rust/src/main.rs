//! `amtl` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `train`     — run one optimization under a chosen update schedule
//!                 (`--method amtl|smtl|semisync`), over shared memory or
//!                 loopback TCP (`--transport inproc|tcp`).
//! * `compare`   — AMTL vs SMTL side by side under one network setting.
//! * `datasets`  — print the Table-II style description of the built-in
//!                 dataset simulators.
//! * `artifacts` — verify the AOT artifact manifest loads and list buckets.
//!
//! Distributed modes (no subcommand — real multi-process deployment):
//!
//! * `--serve <addr>`             — host the central (prox) server.
//! * `--node <t> --connect <addr>` — run task node `t`, which owns only
//!   its task's data; only model vectors cross the wire.
//! * `--replica <addr> --follow <dir>` — serve predictions from a read
//!   replica that bootstraps from the newest snapshot in `<dir>` and
//!   tails the trainer's WAL (plus `predict`, the matching query
//!   client).
//!
//! Examples:
//!
//! ```text
//! amtl train --dataset school-small --reg nuclear --lambda 0.5 --iters 20
//! amtl train --tasks 10 --n 100 --dim 50 --offset 5 --engine pjrt
//! amtl train --method semisync --staleness 4 --tasks 8 --offset 5
//! amtl train --tasks 5 --transport tcp
//! amtl compare --tasks 5 --offset 5 --iters 10
//!
//! # terminal 1                         # terminals 2..N+1 (one per task)
//! amtl --serve 127.0.0.1:7171 \
//!      --tasks 3 --iters 50            amtl --node 0 --connect 127.0.0.1:7171 \
//!                                           --tasks 3 --iters 50
//! ```
//!
//! The serve and node processes must be launched with the same data and
//! problem options (and seed): each derives the same problem definition,
//! and each node keeps only its own task's block. In a real deployment a
//! node would load its local data instead — the protocol is already
//! data-free either way.

use amtl::config::Opts;
use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{schedule_from_cli, Async, MtlProblem, Schedule, Session, Synchronized};
use amtl::data::{public, synthetic, MultiTaskDataset};
use amtl::net::{DelayModel, FaultModel};
use amtl::obs::{fleet, Collector, HealthRules, TraceWriter};
use amtl::optim::coupling::TaskGraph;
use amtl::optim::svd::SvdMode;
use amtl::optim::FormulationSpec;
use amtl::linalg::Mat;
use amtl::runtime::{ComputePool, Engine, PoolConfig};
use amtl::serve::{ModelReplica, PredictClient, ReplicaServer};
use amtl::shard::{run_sharded, ProxShard, ShardMap, ShardRunConfig, TcpShardRouter};
use amtl::transport::wire::MetricsReport;
use amtl::transport::{TcpClient, TcpOptions, TcpServer, Transport, TransportKind};
use amtl::util::json::Json;
use amtl::util::Rng;
use anyhow::{anyhow, bail, ensure, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let opts = match Opts::from_env() {
        Ok(o) => o,
        Err(e) => {
            amtl::log_error!("cli", "{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&opts) {
        amtl::log_error!("cli", "{e:#}");
        std::process::exit(1);
    }
}

fn run(opts: &Opts) -> Result<()> {
    // Logging first, so everything downstream (including option errors)
    // is filtered consistently: --log-level, then AMTL_LOG, then warn.
    amtl::obs::log::init(opts.get("log-level")).map_err(|e| anyhow!("{e}"))?;
    // Size the linalg worker pool before any kernel runs (the count is
    // frozen at first use). 0 = PALLAS_THREADS env var, else all cores.
    let threads = opts.get_usize("threads", 0)?;
    if threads > 0 {
        amtl::linalg::configure_threads(threads);
    }
    // Distributed modes are flag-driven (no subcommand): `--serve <addr>`
    // hosts the central node, `--node <t> --connect <addr>` runs one task
    // node against it.
    if opts.get("serve").is_some() {
        return cmd_serve(opts);
    }
    if opts.get("node").is_some() {
        return cmd_node(opts);
    }
    if opts.get("replica").is_some() {
        return cmd_replica(opts);
    }
    if opts.flag("serve") || opts.flag("node") || opts.flag("replica") {
        bail!(
            "--serve and --replica need an address and --node a task index (see `amtl help`)"
        );
    }
    let cmd = opts.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(opts),
        "predict" => cmd_predict(opts),
        "top" => cmd_top(opts),
        "health" => cmd_health(opts),
        "compare" => cmd_compare(opts),
        "datasets" => cmd_datasets(opts),
        "artifacts" => cmd_artifacts(opts),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (see `amtl help`)"),
    }
}

const HELP: &str = "\
amtl — Asynchronous Multi-Task Learning (Baytas et al., 2016)

USAGE: amtl <command> [options]
       amtl --serve <addr> [options]
       amtl --node <t> --connect <addr> [options]
       amtl --replica <addr> --follow <dir> [options]

COMMANDS:
  train       run one optimization (default method: amtl)
  predict     query a read replica (see SERVING TIER below)
  top         live metrics dashboard for a trainer, replica, or fleet
  health      evaluate fleet health rules; exit nonzero on violations
  compare     run AMTL and SMTL under identical network settings
  datasets    describe the built-in dataset simulators
  artifacts   validate the AOT artifact manifest
  help        this text

DISTRIBUTED MODES (two-terminal walkthrough in README.md):
  --serve ADDR   host the central (prox) server on ADDR, wait for
                 tasks x iters updates, then report and exit
  --node T       run task node T only (owns only task T's data)
  --connect ADDR server address for --node; a comma list (or any one
                 shard of a --shard-peers fleet) auto-routes by task
                 through the shard map
  Launch serve and every node with the SAME data/problem options.

SHARDED SERVER (multi-shard walkthrough in README.md):
  --serve ADDR --shard i/N      host prox shard i of an N-way column
                 partition of V: own commit staging/dedup, own
                 snapshots+WAL under <dir>/shard-i/, serves only its
                 contiguous task range (FetchShardMap bootstraps
                 routers). Separable regularizers (l1, elasticnet,
                 none) shard with zero cross-talk and merge bitwise;
                 the rest get periodic coordination rounds.
  --shard-peers A,B,...         every shard's address, index order;
                 required for coordination rounds and for the final
                 fleet merge that shard 0 reports
  --coord-interval-ms MS        coordination round cadence      [500]
  --linger-ms MS                how long shards i>0 keep serving after
                 finishing, so shard 0's final gather succeeds [3000]
  train --shards N              the same partition in one process
                 (N in-proc shards; see train options below)

SERVING TIER (three-terminal walkthrough in README.md):
  --replica ADDR     serve Predict/FetchStats on ADDR from a read
                     replica; never touches the trainer, only its
                     checkpoint directory
  --follow DIR       the trainer's --checkpoint-dir: bootstrap from the
                     newest snapshot, tail the WAL at byte offsets,
                     hot-swap across checkpoint rotations
  --poll-ms MS       WAL tail poll interval                       [50]
  predict --connect ADDR --task T --x V1,V2,...
                     score one feature vector against task T's column;
                     prints yhat and the model's WAL horizon. A comma
                     list of replicas (one per shard, index order)
                     routes the task to the owning replica
  predict --connect ADDR --stats
                     print the replica's stats frame (lag, latency
                     quantiles, request counters)
  predict --timeout-ms MS   connect/read/write timeout           [5000]
  Load-test a replica with examples/load_gen.rs (BENCH_serve.json).

DATA OPTIONS (synthetic unless --dataset is given):
  --dataset <school|mnist|mtfl|school-small>   simulated public dataset
  --tasks N      number of synthetic tasks          [5]
  --n N          samples per synthetic task         [100]
  --dim D        feature dimension                  [50]
  --rank R       planted shared-subspace rank       [3]
  --noise S      label noise sigma                  [0.1]

PROBLEM OPTIONS:
  --reg <name[:k=v,...]>                           [nuclear]
                 any registered formulation:
                   nuclear     low-rank coupling ||W||_* (SVT prox)
                   l21         joint feature selection ||W||_{2,1}
                   l1          elementwise sparsity
                   elasticnet  ||W||_1 + (gamma/2)||W||_F^2  (:gamma=G)
                   none        decoupled single-task baseline
                   graph       task-relationship coupling tr(W L W^T)
                               (:topology=full|ring,weight=W, or
                               --graph-file)
                   mean        mean-regularized clustering toward the
                               task centroid (incremental centroid)
  --graph-file F similarity graph for --reg graph, JSON:
                 {"tasks": T, "edges": [[i, j, weight], ...]}
  --lambda L     regularization strength            [0.5]
  --eta-scale S  eta = S * 2/L_max, S in (0,1)      [0.5]

RUN OPTIONS:
  --method <amtl|smtl|semisync>                    [amtl]
                 amtl     = asynchronous (Algorithm 1, no barrier)
                 smtl     = synchronized baseline (barrier per round)
                 semisync = bounded staleness (see --staleness)
  --staleness B  semisync: max activations ahead of the slowest node [4]
  --transport <inproc|tcp>                         [inproc]
                 inproc = shared-memory calls (bit-identical baseline)
                 tcp    = loopback sockets: every fetch/commit crosses
                          the real wire protocol
  --iters K      activations per task node          [10]
  --offset U     delay offset in paper units        [0]
  --time-scale MS  wall-clock ms per paper unit     [100]
  --eta-k V      KM relaxation step                 [0.5]
  --dynamic-step enable Eq. III.6 dynamic step
  --svd <online|exact>                             [online]
                 online = incremental Brand SVD prox (the default; exact
                          Jacobi re-anchor every --resvd-every commits)
                 exact  = full Jacobi SVD on every uncached prox
  --resvd-every K  online-SVD exact refresh stride (0 = never) [64]
  --online-svd   legacy alias for --svd online
  --threads N    linalg worker threads (0 = PALLAS_THREADS env, else
                 all cores; parallel results are bitwise serial)  [0]
  --sgd FRAC     stochastic forward steps with this minibatch fraction
  --prox-every K server re-prox stride              [1]
  --shards N     train only: split the server into N in-proc column
                 shards (amtl schedule, inproc transport)        [1]
  --coord-every K  commits between coordination rounds for
                 non-separable formulations under --shards       [64]
  --engine <pjrt|native>                           [native]
  --executors N  PJRT executor threads              [2]
  --artifacts-dir PATH                             [artifacts]
  --record-every K  trajectory sampling stride      [max(1, T*iters/50)]
  --seed S                                         [7]

DURABILITY & MEMBERSHIP (train + distributed modes):
  --checkpoint-dir D   checkpoint the central server into D: versioned
                       snapshots + a commit WAL fsync'd before every ack
  --checkpoint-every K commits between snapshot rotations    [256]
  --resume             recover from --checkpoint-dir (latest valid
                       snapshot + WAL replay) instead of starting fresh;
                       on --node: skip commits the server already has
  --heartbeat-ms MS    elastic membership: nodes heartbeat every MS ms
                       and are evicted after 3 missed intervals (0 = off)
                       [0]

OBSERVABILITY (full metric/trace reference: docs/OBSERVABILITY.md):
  --log-level L        stderr diagnostics filter:
                       error|warn|info|debug|trace (AMTL_LOG env var is
                       the fallback)                          [warn]
  --trace-out PATH     append one JSONL event per activation, commit,
                       prox, checkpoint, and eviction to PATH
                       (train, --serve, --node)
  top --connect ADDR   poll FetchMetrics on a trainer (--serve) or
                       replica address and render a live dashboard:
                       updates/sec, commit staleness p50/p99, per-layer
                       latency histograms, counters
  top --fleet A,B,..   poll several endpoints at once (trainer +
                       replicas; worker NODE rows fan in through the
                       trainer) and render one cluster-wide table with
                       fleet-merged histograms; sharded trainers show
                       their slot in the SHARD i/N column
  top --once           print one snapshot and exit (no screen clearing)
  top --json           machine-readable snapshots (one JSON per poll)
  top --interval-ms MS poll interval                          [1000]
  top --timeout-ms MS  connect/read/write timeout             [5000]

FLEET HEALTH (rule catalog with rationale: docs/OBSERVABILITY.md):
  health --connect ADDR | --fleet A,B,...
                       poll each endpoint (--samples polls,
                       --interval-ms apart), evaluate every health rule,
                       print violations, exit nonzero if any fired
  --staleness-bound B  staleness-runaway bound; set to the run's
                       --staleness under semisync            [off]
  --lag-bound N        replica lag threshold (commits)      [5000]
                       (--max-replica-lag is a legacy alias)
  --eviction-storm N   evictions per window threshold          [3]
  --min-rate R         updates/sec floor (0 disables)          [0]
  --fsync-p99-us U     wal fsync p99 threshold (us)       [100000]
                       (--wal-fsync-p99-us is a legacy alias)
  --samples N          polls per endpoint before judging       [2]
  --json               machine-readable verdict
";

/// Assemble the dataset from CLI options.
fn build_dataset(opts: &Opts, rng: &mut Rng) -> Result<MultiTaskDataset> {
    if let Some(name) = opts.get("dataset") {
        return public::by_name(name, rng)
            .ok_or_else(|| anyhow!("unknown dataset '{name}' (school|mnist|mtfl|school-small)"));
    }
    let t = opts.get_usize("tasks", 5)?;
    let n = opts.get_usize("n", 100)?;
    let d = opts.get_usize("dim", 50)?;
    let rank = opts.get_usize("rank", 3)?;
    let noise = opts.get_f64("noise", 0.1)?;
    Ok(synthetic::lowrank_regression(&vec![n; t], d, rank.min(d), noise, rng))
}

fn build_problem(opts: &Opts, rng: &mut Rng) -> Result<MtlProblem> {
    let ds = build_dataset(opts, rng)?;
    // `--reg` resolves through the open formulation registry: classic
    // kinds, the new couplings (graph, mean), and `name:key=value` params
    // all go through one parser.
    let mut spec = FormulationSpec::parse(&opts.get_or("reg", "nuclear"))?;
    if let Some(path) = opts.get("graph-file") {
        ensure!(
            spec.name() == "graph",
            "--graph-file only applies to --reg graph (got --reg {})",
            spec.name()
        );
        spec = spec.with_graph(TaskGraph::from_json_file(std::path::Path::new(path))?);
    }
    let lambda = opts.get_f64("lambda", 0.5)?;
    let eta_scale = opts.get_f64("eta-scale", 0.5)?;
    MtlProblem::try_new(ds, spec, lambda, eta_scale, rng)
}

struct RunOpts {
    iters: usize,
    sgd_fraction: Option<f64>,
    offset: f64,
    time_scale: Duration,
    eta_k: f64,
    dynamic: bool,
    svd: SvdMode,
    resvd_every: u64,
    prox_every: u64,
    engine: Engine,
    executors: usize,
    artifacts_dir: String,
    record_every: u64,
    transport: TransportKind,
    seed: u64,
    checkpoint_dir: Option<std::path::PathBuf>,
    checkpoint_every: u64,
    resume: bool,
    heartbeat: Option<Duration>,
    trace: Option<Arc<TraceWriter>>,
}

fn run_opts(opts: &Opts, t: usize) -> Result<RunOpts> {
    let iters = opts.get_usize("iters", 10)?;
    let default_record = ((t * iters) as u64 / 50).max(1);
    let sgd = opts.get_f64("sgd", 0.0)?;
    let transport = TransportKind::parse(&opts.get_or("transport", "inproc"))?;
    // `--online-svd` predates `--svd` and forces the online backend.
    // (Queried unconditionally so reject_unknown never trips on it.)
    let legacy_online = opts.flag("online-svd");
    let svd = match opts.get("svd") {
        Some(v) => SvdMode::parse(v)?,
        None if legacy_online => SvdMode::Online,
        None => SvdMode::default(),
    };
    // Contradictory-flag check (mirrored in RunConfig::validate for
    // programmatic callers): an explicit refresh stride is meaningless
    // under the exact backend and used to pass silently.
    if opts.get("resvd-every").is_some() && svd == SvdMode::Exact {
        bail!("--resvd-every only applies to --svd online (exact recomputes every prox)");
    }
    Ok(RunOpts {
        iters,
        sgd_fraction: if sgd > 0.0 { Some(sgd) } else { None },
        offset: opts.get_f64("offset", 0.0)?,
        time_scale: Duration::from_millis(opts.get_u64("time-scale", 100)?),
        eta_k: opts.get_f64("eta-k", 0.5)?,
        dynamic: opts.flag("dynamic-step"),
        svd,
        resvd_every: if svd == SvdMode::Exact {
            amtl::coordinator::DEFAULT_RESVD_EVERY
        } else {
            opts.get_u64("resvd-every", amtl::coordinator::DEFAULT_RESVD_EVERY)?
        },
        prox_every: opts.get_u64("prox-every", 1)?,
        engine: Engine::parse(&opts.get_or("engine", "native"))
            .ok_or_else(|| anyhow!("bad --engine"))?,
        executors: opts.get_usize("executors", 2)?,
        artifacts_dir: opts.get_or("artifacts-dir", "artifacts"),
        record_every: opts.get_u64("record-every", default_record)?,
        transport,
        seed: opts.get_u64("seed", 7)?,
        checkpoint_dir: opts.get("checkpoint-dir").map(std::path::PathBuf::from),
        checkpoint_every: opts
            .get_u64("checkpoint-every", amtl::persist::DEFAULT_SNAPSHOT_EVERY)?,
        resume: opts.flag("resume"),
        heartbeat: match opts.get_u64("heartbeat-ms", 0)? {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        trace: match opts.get("trace-out") {
            Some(path) => {
                Some(Arc::new(TraceWriter::create(std::path::Path::new(path))?))
            }
            None => None,
        },
    })
}

/// Configure a [`Session`] builder from the parsed run options (the one
/// wiring path every method shares).
fn session<'p>(
    problem: &'p MtlProblem,
    pool: Option<&'p ComputePool>,
    ro: &RunOpts,
    schedule: Box<dyn Schedule>,
) -> amtl::coordinator::SessionBuilder<'p> {
    Session::builder(problem)
        .engine(ro.engine)
        .pool(pool)
        .iters_per_node(ro.iters)
        .sgd_fraction(ro.sgd_fraction)
        .time_scale(ro.time_scale)
        .eta_k(ro.eta_k)
        .dynamic_step(ro.dynamic)
        .prox_every(ro.prox_every)
        .record_every(ro.record_every)
        .svd(ro.svd)
        .resvd_every(ro.resvd_every)
        .seed(ro.seed)
        .checkpoint_dir(ro.checkpoint_dir.clone())
        .checkpoint_every(ro.checkpoint_every)
        .resume(ro.resume)
        .heartbeat(ro.heartbeat)
        .trace(ro.trace.clone())
        .paper_offset(ro.offset)
        .transport(ro.transport)
        .schedule_box(schedule)
}

/// Resolve `--method` (+ `--staleness`) into a schedule (the shared,
/// unit-tested helper rejects a staleness bound on schedules without a
/// staleness concept).
fn parse_schedule(opts: &Opts) -> Result<Box<dyn Schedule>> {
    let method = opts.get_or("method", "amtl");
    let staleness = match opts.get("staleness") {
        Some(_) => Some(opts.get_u64("staleness", 4)?),
        None => None,
    };
    schedule_from_cli(&method, staleness)
}

fn make_pool(ro: &RunOpts) -> Result<Option<ComputePool>> {
    if ro.engine == Engine::Pjrt {
        Ok(Some(ComputePool::new(PoolConfig {
            executors: ro.executors,
            artifacts_dir: ro.artifacts_dir.clone().into(),
        })?))
    } else {
        Ok(None)
    }
}

fn cmd_train(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let schedule = parse_schedule(opts)?;
    let ro = run_opts(opts, problem.t())?;
    let shards = opts.get_usize("shards", 1)?;
    let coord_every = opts.get_u64("coord-every", amtl::shard::DEFAULT_COORD_EVERY)?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    if shards > 1 {
        ensure!(
            opts.get_or("method", "amtl") == "amtl",
            "--shards runs the amtl (async) schedule only"
        );
        ensure!(
            ro.transport.name() == "inproc",
            "--shards is the in-process driver; run multi-process shards with \
             `amtl --serve <addr> --shard i/N` instead"
        );
        return cmd_train_sharded(&problem, &ro, shards, coord_every);
    }

    println!("dataset: {}", problem.dataset.describe());
    println!(
        "problem: reg={} lambda={} eta={:.3e} L={:.3e} transport={} svd={} threads={}",
        problem.reg_name(),
        problem.lambda,
        problem.eta,
        problem.l_max,
        ro.transport.name(),
        ro.svd.name(),
        amtl::linalg::threads(),
    );
    let pool = make_pool(&ro)?;
    let result = session(&problem, pool.as_ref(), &ro, schedule).build()?.run()?;

    println!("{}", result.summary());
    let objs = result.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));
    for (secs, ver, obj) in &objs {
        println!("  t={secs:8.3}s  k={ver:6}  F={obj:.6}");
    }
    println!(
        "final objective: {:.6}  (train RMSE {:.4})",
        problem.objective(&result.w_final),
        problem.train_rmse(&result.w_final)
    );
    Ok(())
}

/// `train --shards N`: the in-process sharded run — one column-range
/// prox shard per partition, one free-running worker per task routed by
/// the shard map (see `docs/ARCHITECTURE.md` § "Sharded server").
fn cmd_train_sharded(
    problem: &MtlProblem,
    ro: &RunOpts,
    shards: usize,
    coord_every: u64,
) -> Result<()> {
    println!("dataset: {}", problem.dataset.describe());
    println!(
        "problem: reg={} lambda={} eta={:.3e} L={:.3e} shards={shards} threads={}",
        problem.reg_name(),
        problem.lambda,
        problem.eta,
        problem.l_max,
        amtl::linalg::threads(),
    );
    let mut cfg = ShardRunConfig::new(shards, ro.iters, ro.eta_k, ro.seed);
    cfg.coord_every = coord_every.max(1);
    cfg.persist = ro.checkpoint_dir.clone().map(|d| (d, ro.checkpoint_every));
    cfg.resume = ro.resume;
    if let Some((dir, every)) = &cfg.persist {
        println!(
            "{} {} (snapshot every {every} commits, one store per shard)",
            if cfg.resume { "resuming from" } else { "checkpointing to" },
            dir.display()
        );
    }
    let res = run_sharded(problem, &cfg)?;
    println!(
        "sharded run complete: {} updates over {shards} shards ({})",
        res.updates,
        if res.separable {
            "separable: no coordination traffic".to_string()
        } else {
            format!("{} coordination rounds", res.rounds)
        },
    );
    for (t, s) in res.worker_stats.iter().enumerate() {
        println!("  node {t}: {} updates", s.updates);
    }
    println!(
        "final objective: {:.6}  (train RMSE {:.4})",
        res.objective,
        problem.train_rmse(&res.merged_w)
    );
    Ok(())
}

fn cmd_compare(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    println!("dataset: {}", problem.dataset.describe());
    let pool = make_pool(&ro)?;

    let amtl_res = session(&problem, pool.as_ref(), &ro, Box::new(Async))
        .build()?
        .run()?;
    let smtl_res = session(&problem, pool.as_ref(), &ro, Box::new(Synchronized))
        .build()?
        .run()?;

    println!("{}", amtl_res.summary());
    println!("{}", smtl_res.summary());
    println!(
        "AMTL objective {:.6} | SMTL objective {:.6} | speedup {:.2}x",
        problem.objective(&amtl_res.w_final),
        problem.objective(&smtl_res.w_final),
        smtl_res.wall_time.as_secs_f64() / amtl_res.wall_time.as_secs_f64().max(1e-9),
    );
    Ok(())
}

/// `--serve <addr>`: host the central node. Accepts task-node connections,
/// serves prox columns, commits their updates, and exits (with a final
/// report) once `tasks x iters` updates have landed.
fn cmd_serve(opts: &Opts) -> Result<()> {
    let addr = opts.require("serve").map_err(|e| anyhow!("{e}"))?;
    // `--shard i/N` switches to the sharded deployment: this process
    // hosts one column-range prox shard, not the whole-model server.
    if let Some(spec) = opts.get("shard") {
        let spec = spec.to_string();
        return cmd_serve_shard(opts, &addr, &spec);
    }
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    let t_count = problem.t();
    // The same construction path Session::run uses — the in-proc and
    // two-process deployments cannot drift apart.
    let cfg = amtl::coordinator::RunConfig {
        iters_per_node: ro.iters,
        prox_every: ro.prox_every,
        record_every: ro.record_every,
        svd: ro.svd,
        resvd_every: ro.resvd_every,
        seed: ro.seed,
        checkpoint_dir: ro.checkpoint_dir.clone(),
        checkpoint_every: ro.checkpoint_every,
        resume: ro.resume,
        heartbeat: ro.heartbeat,
        trace: ro.trace.clone(),
        ..Default::default()
    };
    let (state, server, recorder) = cfg.build_server(&problem)?;
    if ro.resume {
        println!(
            "resumed from {}: {} updates already applied ({} wal entries replayed)",
            ro.checkpoint_dir.as_ref().map(|d| d.display().to_string()).unwrap_or_default(),
            state.version(),
            server.wal_replayed(),
        );
    } else if let Some(dir) = &ro.checkpoint_dir {
        println!("checkpointing to {} (snapshot every {} commits)", dir.display(), ro.checkpoint_every);
    }
    let mut handle = TcpServer::spawn(&addr, Arc::clone(&server), Some(Arc::clone(&recorder)))?;

    let expected = (t_count * ro.iters) as u64;
    println!("central node serving on {}", handle.addr());
    println!("dataset: {}", problem.dataset.describe());
    println!(
        "problem: reg={} lambda={} eta={:.3e}; waiting for {t_count} nodes x {} activations = {expected} updates",
        problem.reg_name(),
        problem.lambda,
        problem.eta,
        ro.iters,
    );
    println!(
        "start task nodes with: amtl --node <t> --connect {} [same data/problem options]",
        handle.addr()
    );

    let report_stride = (expected / 10).max(1);
    let mut last_report = 0u64;
    let mut last_progress = (0u64, std::time::Instant::now());
    loop {
        std::thread::sleep(Duration::from_millis(100));
        // With membership enabled the serve loop is the traffic-free
        // poller: sweep so silent nodes are evicted even when no other
        // node's request would have done it.
        if let Some(registry) = server.registry() {
            for t in registry.sweep() {
                println!(
                    "  node {t} evicted (silent past the heartbeat timeout); \
                     not waiting for its remaining budget"
                );
            }
        }
        let v = state.version();
        if v >= last_report + report_stride && v < expected {
            println!("  {v}/{expected} updates committed");
            last_report = v;
        }
        // Exit on per-node progress: a node is done when its budget is
        // committed, or when membership says it is gone (evicted on
        // timeout, or departed politely without finishing).
        let node_done = |t: usize| {
            state.col_version(t) >= ro.iters as u64
                || server
                    .registry()
                    .map(|r| {
                        matches!(
                            r.status(t),
                            amtl::coordinator::NodeStatus::Evicted
                                | amtl::coordinator::NodeStatus::Left
                        )
                    })
                    .unwrap_or(false)
        };
        if (0..t_count).all(node_done) {
            break;
        }
        // No hard timeout (node budgets are theirs to pace), but surface a
        // stall so a dead node is diagnosable: per-node counts show which
        // one went missing. Ctrl-C to abandon the run.
        if v > last_progress.0 {
            last_progress = (v, std::time::Instant::now());
        } else if last_progress.1.elapsed() > Duration::from_secs(30) {
            let counts: Vec<String> =
                (0..t_count).map(|t| format!("node {t}: {}", state.col_version(t))).collect();
            println!(
                "  no progress for 30s at {v}/{expected} updates ({}); waiting — Ctrl-C to abort",
                counts.join(", ")
            );
            last_progress = (v, std::time::Instant::now());
        }
    }
    // Let trailing Pushed responses flush before tearing connections
    // down. (Commits are exactly-once — resends are deduplicated on the
    // node's activation counter — so this grace window is only about
    // letting final responses reach their nodes.)
    std::thread::sleep(Duration::from_millis(500));
    // Durability epilogue: fsync the WAL and cut a final snapshot so a
    // later `--resume` (or offline inspection) sees the finished state.
    server.sync_persist()?;
    if let Some(cp) = server.checkpointer() {
        cp.checkpoint_now(&server)?;
    }
    handle.shutdown();
    if let Some(tr) = &ro.trace {
        tr.flush();
    }

    println!("run complete: {} updates, {} proxes", state.version(), server.prox_count());
    if server.checkpoints_written() > 0 || server.wal_replayed() > 0 {
        println!(
            "  durability: {} checkpoints written, {} wal entries replayed at startup",
            server.checkpoints_written(),
            server.wal_replayed()
        );
    }
    for t in 0..t_count {
        println!("  node {t}: {} updates", state.col_version(t));
    }
    let w = server.final_w();
    if let Ok(recorder) = Arc::try_unwrap(recorder) {
        for p in recorder.into_points() {
            println!(
                "  t={:8.3}s  k={:6}  F={:.6}",
                p.elapsed.as_secs_f64(),
                p.version,
                problem.objective(&problem.prox_map(&p.v))
            );
        }
    }
    println!(
        "final objective: {:.6}  (train RMSE {:.4})",
        problem.objective(&w),
        problem.train_rmse(&w)
    );
    Ok(())
}

/// Parse `--shard i/N` into `(index, count)`.
fn parse_shard_spec(spec: &str) -> Result<(usize, usize)> {
    let parse = || -> Option<(usize, usize)> {
        let (i, n) = spec.split_once('/')?;
        Some((i.trim().parse().ok()?, n.trim().parse().ok()?))
    };
    let (index, count) =
        parse().ok_or_else(|| anyhow!("--shard expects i/N (e.g. --shard 0/2), got '{spec}'"))?;
    ensure!(count > 0, "--shard {spec}: shard count must be positive");
    ensure!(index < count, "--shard {spec}: index must be below the shard count");
    Ok((index, count))
}

/// Dial shard `s`'s serve address (lazily, reusing an open client).
fn shard_client<'a>(
    clients: &'a mut [Option<TcpClient>],
    map: &ShardMap,
    s: usize,
) -> Result<&'a mut TcpClient> {
    if clients[s].is_none() {
        let addr = &map.addrs[s];
        ensure!(!addr.is_empty(), "shard {s} has no address (start shards with --shard-peers)");
        clients[s] = Some(TcpClient::connect(addr.as_str(), TcpOptions::default())?);
    }
    Ok(clients[s].as_mut().expect("just connected"))
}

/// Gather every shard's raw `V` slice into the full d×T iterate — the
/// own slice read in-process (through the checkpoint quiesce gate),
/// peers over `FetchSlice`. Returns each shard's commit count alongside.
fn gather_fleet(
    shard: &ProxShard,
    map: &ShardMap,
    clients: &mut [Option<TcpClient>],
) -> Result<(Vec<u64>, Mat)> {
    let d = map.d as usize;
    let mut full = Mat::zeros(d, map.tasks());
    let mut versions = vec![0u64; map.shards()];
    for s in 0..map.shards() {
        let (v, slice) = if s == shard.index() {
            shard.raw_slice()
        } else {
            shard_client(clients, map, s)?.fetch_slice()?
        };
        let range = map.range(s);
        ensure!(
            slice.rows() == d && slice.cols() == range.len(),
            "shard {s} slice is {}x{}, expected {}x{}",
            slice.rows(),
            slice.cols(),
            d,
            range.len()
        );
        versions[s] = v;
        for (j, t) in range.enumerate() {
            full.set_col(t, slice.col(j));
        }
    }
    Ok((versions, full))
}

/// One cross-process coordination round, driven by shard 0: quiesce +
/// gather every slice, apply the true full-matrix prox once, scatter
/// each shard's columns back (`PushProxSlice`; the own slice installs
/// directly). See `docs/ARCHITECTURE.md` § "Sharded server".
fn coordination_round(
    shard: &ProxShard,
    map: &ShardMap,
    clients: &mut [Option<TcpClient>],
    full_reg: &mut dyn amtl::optim::SharedProx,
    eta: f64,
    round: u64,
) -> Result<()> {
    let (_versions, mut w) = gather_fleet(shard, map, clients)?;
    full_reg.prox(&mut w, eta);
    for s in 0..map.shards() {
        let range = map.range(s);
        let mut slice = Mat::zeros(map.d as usize, range.len());
        for (j, t) in range.clone().enumerate() {
            slice.set_col(j, w.col(t));
        }
        if s == shard.index() {
            shard.install_round(round, slice)?;
        } else {
            shard_client(clients, map, s)?.push_prox_slice(round, &slice)?;
        }
    }
    Ok(())
}

/// Shard 0's end-of-run fleet epilogue: wait until every shard's commit
/// count reaches its budget (stall-guarded), gather the slices, apply
/// the full-matrix prox once, and report the merged objective — the
/// line a multi-process convergence check (CI's shard-smoke) greps for.
fn fleet_wait_and_merge(
    shard: &ProxShard,
    map: &ShardMap,
    iters: usize,
    problem: &MtlProblem,
) -> Result<()> {
    let expected: Vec<u64> = (0..map.shards()).map(|s| (map.cols(s) * iters) as u64).collect();
    let mut clients: Vec<Option<TcpClient>> = (0..map.shards()).map(|_| None).collect();
    let mut best: Option<(Vec<u64>, Mat)> = None;
    let started = std::time::Instant::now();
    let mut last_progress = (0u64, std::time::Instant::now());
    loop {
        match gather_fleet(shard, map, &mut clients) {
            Ok((versions, full)) => {
                let total: u64 = versions.iter().sum();
                let done = versions.iter().zip(&expected).all(|(v, e)| v >= e);
                best = Some((versions, full));
                if done {
                    break;
                }
                if total > last_progress.0 {
                    last_progress = (total, std::time::Instant::now());
                } else if last_progress.1.elapsed() > Duration::from_secs(60) {
                    amtl::log_warn!("shard", "fleet made no progress for 60s; merging as-is");
                    break;
                }
            }
            Err(e) => {
                // Redial everything next attempt; a restarting peer is
                // indistinguishable from a slow one until the deadline.
                for c in clients.iter_mut() {
                    *c = None;
                }
                if started.elapsed() > Duration::from_secs(60) {
                    amtl::log_warn!("shard", "fleet gather failed past the deadline: {e:#}");
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    for c in clients.iter_mut().flatten() {
        let _ = c.close();
    }
    let Some((versions, v_full)) = best else {
        println!("fleet merge skipped: no peer shard answered FetchSlice");
        return Ok(());
    };
    let mut w = v_full;
    let mut reg = problem.regularizer();
    reg.prox(&mut w, problem.eta);
    println!(
        "fleet commits per shard: {}",
        versions.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
    );
    println!(
        "merged final objective: {:.6}  (train RMSE {:.4})",
        problem.objective(&w),
        problem.train_rmse(&w)
    );
    Ok(())
}

/// `--serve <addr> --shard i/N`: host prox shard `i` of an `N`-way
/// column partition. The shard answers `FetchShardMap` so routers can
/// bootstrap, serves/commits only its own task range, and checkpoints
/// into `<dir>/shard-i/`. For non-separable formulations shard 0 also
/// drives the periodic coordination round across `--shard-peers`.
fn cmd_serve_shard(opts: &Opts, addr: &str, spec: &str) -> Result<()> {
    let (index, count) = parse_shard_spec(spec)?;
    let peers = match opts.get("shard-peers") {
        Some(list) => Some(split_addr_list(list)?),
        None => None,
    };
    let coord_interval = Duration::from_millis(opts.get_u64("coord-interval-ms", 500)?.max(10));
    let linger = Duration::from_millis(opts.get_u64("linger-ms", 3000)?);
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    ensure!(
        count <= problem.t(),
        "--shard {spec}: {count} shards over {} tasks leaves empty shards",
        problem.t()
    );
    if let Some(p) = &peers {
        ensure!(
            p.len() == count,
            "--shard-peers lists {} addresses for {count} shards (every shard, index order)",
            p.len()
        );
    }

    let mut map = ShardMap::uniform(problem.d(), problem.t(), count);
    if let Some(p) = &peers {
        map = map.with_addrs(p.clone())?;
    }
    let map = Arc::new(map);
    let proto = problem.regularizer();
    let shard = if ro.resume {
        let dir = ro
            .checkpoint_dir
            .as_ref()
            .ok_or_else(|| anyhow!("--resume requires --checkpoint-dir"))?;
        Arc::new(ProxShard::resume(
            Arc::clone(&map),
            index,
            proto.as_ref(),
            problem.eta,
            dir,
            ro.checkpoint_every,
        )?)
    } else {
        if let Some(dir) = &ro.checkpoint_dir {
            // Every shard writes the (identical) routing file, so any one
            // shard's parent directory is enough to resume or follow.
            map.save(dir)?;
        }
        let persist = ro.checkpoint_dir.as_deref().map(|d| (d, ro.checkpoint_every));
        Arc::new(ProxShard::create(Arc::clone(&map), index, proto.as_ref(), problem.eta, persist)?)
    };
    // Fleet rows (`amtl top --fleet`) key their SHARD column off these.
    amtl::obs::global().set_gauge("shard.index", index as u64);
    amtl::obs::global().set_gauge("shard.count", count as u64);

    let range = shard.range();
    let owned = range.len();
    let expected = (owned * ro.iters) as u64;
    if ro.resume {
        println!(
            "shard {index}/{count} resumed from {}: {} updates already applied ({} wal entries replayed)",
            ro.checkpoint_dir.as_ref().map(|d| d.display().to_string()).unwrap_or_default(),
            shard.server().state().version(),
            shard.server().wal_replayed(),
        );
    } else if let Some(dir) = &ro.checkpoint_dir {
        println!(
            "shard {index}/{count} checkpointing to {} (snapshot every {} commits)",
            ShardMap::shard_dir(dir, index).display(),
            ro.checkpoint_every
        );
    }
    let mut handle = TcpServer::spawn_shard(addr, Arc::clone(&shard), None)?;
    println!(
        "prox shard {index}/{count} serving on {} — owns tasks {}..{} ({owned} of {})",
        handle.addr(),
        range.start,
        range.end,
        problem.t(),
    );
    println!("dataset: {}", problem.dataset.describe());
    println!(
        "problem: reg={} ({}) lambda={} eta={:.3e}; waiting for {owned} nodes x {} activations = {expected} updates",
        problem.reg_name(),
        if shard.is_coordinated() { "coordinated" } else { "separable" },
        problem.lambda,
        problem.eta,
        ro.iters,
    );
    if shard.is_coordinated() && peers.is_none() {
        amtl::log_warn!(
            "shard",
            "non-separable formulation without --shard-peers: no coordination \
             rounds will run and fetches serve the raw iterate"
        );
    }

    // Shard 0 of a coordinated fleet drives the gather→prox→scatter
    // round on a wall-clock cadence (commit-stride triggering would need
    // a cross-process commit counter; the cadence needs none).
    let stop = Arc::new(AtomicBool::new(false));
    let driver = if shard.is_coordinated() && peers.is_some() && index == 0 && count > 1 {
        let shard = Arc::clone(&shard);
        let map = Arc::clone(&map);
        let stop = Arc::clone(&stop);
        let mut full_reg = problem.regularizer();
        let eta = problem.eta;
        Some(std::thread::spawn(move || {
            let mut clients: Vec<Option<TcpClient>> = (0..map.shards()).map(|_| None).collect();
            let mut round = shard.round();
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(coord_interval);
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                match coordination_round(&shard, &map, &mut clients, full_reg.as_mut(), eta, round + 1)
                {
                    Ok(()) => round += 1,
                    Err(e) => {
                        amtl::log_warn!(
                            "shard",
                            "coordination round {} failed (will retry): {e:#}",
                            round + 1
                        );
                        for c in clients.iter_mut() {
                            *c = None;
                        }
                    }
                }
            }
            for c in clients.iter_mut().flatten() {
                let _ = c.close();
            }
        }))
    } else {
        None
    };

    let server = shard.server();
    let state = server.state();
    let report_stride = (expected / 10).max(1);
    let mut last_report = 0u64;
    let mut last_progress = (0u64, std::time::Instant::now());
    loop {
        std::thread::sleep(Duration::from_millis(100));
        if let Some(registry) = server.registry() {
            for lt in registry.sweep() {
                println!(
                    "  task {} evicted (silent past the heartbeat timeout); \
                     not waiting for its remaining budget",
                    range.start + lt
                );
            }
        }
        let v = state.version();
        if v >= last_report + report_stride && v < expected {
            println!("  {v}/{expected} updates committed on shard {index}");
            last_report = v;
        }
        let node_done = |lt: usize| {
            state.col_version(lt) >= ro.iters as u64
                || server
                    .registry()
                    .map(|r| {
                        matches!(
                            r.status(lt),
                            amtl::coordinator::NodeStatus::Evicted
                                | amtl::coordinator::NodeStatus::Left
                        )
                    })
                    .unwrap_or(false)
        };
        if (0..owned).all(node_done) {
            break;
        }
        if v > last_progress.0 {
            last_progress = (v, std::time::Instant::now());
        } else if last_progress.1.elapsed() > Duration::from_secs(30) {
            let counts: Vec<String> = (0..owned)
                .map(|lt| format!("task {}: {}", range.start + lt, state.col_version(lt)))
                .collect();
            println!(
                "  no progress for 30s at {v}/{expected} updates ({}); waiting — Ctrl-C to abort",
                counts.join(", ")
            );
            last_progress = (v, std::time::Instant::now());
        }
    }
    // Same grace window as the whole-model serve loop: let trailing acks
    // flush (commits are deduplicated, so this is purely about responses).
    std::thread::sleep(Duration::from_millis(500));

    // Shard 0 waits for the whole fleet and prints the merged objective;
    // the others linger so its final gather still finds them serving.
    if index == 0 && count > 1 && peers.is_some() {
        fleet_wait_and_merge(&shard, &map, ro.iters, &problem)?;
    }
    stop.store(true, Ordering::SeqCst);
    if let Some(h) = driver {
        let _ = h.join();
    }
    server.sync_persist()?;
    if let Some(cp) = server.checkpointer() {
        cp.checkpoint_now(server)?;
    }
    if index != 0 && peers.is_some() {
        std::thread::sleep(linger);
    }
    handle.shutdown();
    if let Some(tr) = &ro.trace {
        tr.flush();
    }

    println!(
        "shard {index}/{count} run complete: {} updates, {} proxes, {} coordination rounds",
        state.version(),
        server.prox_count(),
        shard.round(),
    );
    if server.checkpoints_written() > 0 || server.wal_replayed() > 0 {
        println!(
            "  durability: {} checkpoints written, {} wal entries replayed at startup",
            server.checkpoints_written(),
            server.wal_replayed()
        );
    }
    for lt in 0..owned {
        println!("  task {}: {} updates", range.start + lt, state.col_version(lt));
    }
    Ok(())
}

/// `--node <t> --connect <addr>`: run one task node. The process derives
/// the shared problem definition, keeps only task `t`'s data, and speaks
/// the wire protocol to the serving process — the privacy boundary of the
/// paper, as an actual process boundary.
fn cmd_node(opts: &Opts) -> Result<()> {
    let t = opts.get_usize("node", 0)?;
    let addr = opts.require("connect").map_err(|e| anyhow!("{e}"))?;
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    let problem = build_problem(opts, &mut rng)?;
    let ro = run_opts(opts, problem.t())?;
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    ensure!(
        t < problem.t(),
        "--node {t} out of range: the problem has {} tasks",
        problem.t()
    );

    let task = &problem.dataset.tasks[t];
    println!(
        "task node {t}: owns '{}' ({} samples x {} features); only model vectors cross the wire",
        task.name,
        task.n(),
        task.d()
    );
    let pool = make_pool(&ro)?;
    let mut computes =
        amtl::runtime::make_task_computes(ro.engine, pool.as_ref(), std::slice::from_ref(task))?;
    let mut compute = computes.pop().expect("one compute for one task");

    // `--connect` takes one address (whole-model server) or a comma
    // list of shard addresses; a sharded fleet is auto-detected by
    // fetching the shard map from the first reachable seed, so a single
    // `--shard-peers`-configured shard address is also enough.
    let seeds = split_addr_list(&addr)?;
    let transport: Box<dyn Transport> = match TcpShardRouter::connect(&seeds, TcpOptions::default())
    {
        Ok(router) => {
            println!(
                "connected to a {}-shard fleet via {addr}; server eta = {:.3e}",
                router.map().shards(),
                router.eta()
            );
            Box::new(router)
        }
        // A whole-model server refuses FetchShardMap; fall back to the
        // direct client. Any other failure (unreachable, map/seed
        // mismatch) is real and propagates.
        Err(e) if seeds.len() == 1 && format!("{e:#}").contains("not sharded") => {
            let client = TcpClient::connect(seeds[0].as_str(), TcpOptions::default())?;
            println!("connected to {addr}; server eta = {:.3e}", client.eta());
            Box::new(client)
        }
        Err(e) => return Err(e),
    };

    let delay = if ro.offset > 0.0 {
        DelayModel::paper_offset(ro.time_scale.mul_f64(ro.offset))
    } else {
        DelayModel::None
    };
    // Fork this node's RNG stream exactly the way the in-proc session
    // does (`Rng::fork` advances the root, so the session's node-t stream
    // is the (t+1)-th sequential fork): a two-process run on the same
    // seed sees the same randomness as `train` would.
    let mut root = Rng::new(ro.seed);
    let mut node_rng = root.fork(0);
    for i in 1..=t {
        node_rng = root.fork(i as u64);
    }
    let ctx = WorkerCtx {
        t,
        iters: ro.iters,
        transport,
        controller: Arc::new(StepController::new(
            KmSchedule::fixed(ro.eta_k),
            ro.dynamic,
            problem.t(),
            amtl::coordinator::RunConfig::default().dyn_window,
        )),
        delay,
        faults: FaultModel::None,
        sgd_fraction: ro.sgd_fraction,
        time_scale: ro.time_scale,
        sink: None,
        rng: node_rng,
        gate: None,
        // The worker registers on start and heartbeats through long
        // delays; with --resume it skips the commits the server already
        // has (a restarted node catches up instead of redoing work).
        heartbeat: ro.heartbeat,
        resume: ro.resume,
        trace: ro.trace.clone(),
        // Piggyback this node's registry snapshot to the trainer on the
        // heartbeat cadence (or ~1 s without membership), so `amtl top
        // --connect <trainer>` shows a NODE row for this process.
        metrics_stride: ro.heartbeat.or(Some(Duration::from_secs(1))),
    };
    let stats = run_worker(ctx, compute.as_mut())?;
    if let Some(tr) = &ro.trace {
        tr.flush();
    }
    println!(
        "node {t} done: {} updates ({} dropped), delay {:.2}s, compute {:.2}s, backward wait {:.2}s, last task loss {:.6}",
        stats.updates,
        stats.dropped,
        stats.total_delay_secs,
        stats.compute_secs,
        stats.backward_wait_secs,
        stats.last_task_loss,
    );
    Ok(())
}

/// `--replica <addr> --follow <dir>`: run a read replica. Bootstraps
/// from the newest snapshot in the followed checkpoint directory, tails
/// the WAL, and serves the predict protocol until killed. Needs no
/// data/problem options — everything it serves comes from the
/// directory's artifacts.
fn cmd_replica(opts: &Opts) -> Result<()> {
    let addr = opts.require("replica").map_err(|e| anyhow!("{e}"))?;
    let dir = std::path::PathBuf::from(opts.require("follow").map_err(|e| anyhow!("{e}"))?);
    let poll = Duration::from_millis(opts.get_u64("poll-ms", 50)?.max(1));
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    let replica = ModelReplica::follow(&dir, poll);
    let handle = ReplicaServer::spawn(&addr, &replica)?;
    println!("replica serving on {} (following {})", handle.addr(), dir.display());
    println!(
        "query with: amtl predict --connect {} --task <t> --x <v1,v2,...>  (or --stats)",
        handle.addr()
    );
    if !replica.wait_ready(Duration::from_millis(250)) {
        println!(
            "waiting for a first snapshot in {} (start the trainer with --checkpoint-dir)",
            dir.display()
        );
    }
    // Serve until killed; surface the feed's progress without spamming a
    // quiet terminal (the uptime/latency fields churn on their own, so
    // only the state-bearing counters gate a report line).
    let mut last = (0u64, 0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
    loop {
        std::thread::sleep(Duration::from_secs(5));
        let s = replica.stats();
        let now = (
            s.model_seq,
            s.latest_seq,
            s.applied_entries,
            s.predictions,
            s.errors,
            s.bootstraps,
            s.hot_swaps,
        );
        if now != last {
            println!(
                "  model seq {} (lag {}): {} wal entries applied, {} bootstraps, {} hot-swaps; \
                 {} predictions ({} errors), p50 {}us p99 {}us",
                s.model_seq,
                s.lag(),
                s.applied_entries,
                s.bootstraps,
                s.hot_swaps,
                s.predictions,
                s.errors,
                s.p50_us,
                s.p99_us,
            );
            last = now;
        }
    }
}

/// `predict --connect <addr>`: one-shot query client for a replica.
/// `--task T --x v1,v2,...` scores a vector; `--stats` prints the
/// replica's counters instead.
fn cmd_predict(opts: &Opts) -> Result<()> {
    let addr = opts.require("connect").map_err(|e| anyhow!("{e}"))?;
    let timeout = Duration::from_millis(opts.get_u64("timeout-ms", 5000)?.max(1));
    let want_stats = opts.flag("stats");
    let task = opts.get_usize("task", 0)?;
    let raw_x = opts.get("x").map(|s| s.to_string());
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    // A sharded deployment runs one replica per shard (each follows one
    // `shard-i/` store); `--connect a,b,...` lists them in shard order
    // and the query routes by task: replica `s` serves the global tasks
    // `[sum of earlier replicas' task counts ..)` — the same contiguous
    // partition the shard map used.
    let addrs = split_addr_list(&addr)?;
    if addrs.len() > 1 && !want_stats {
        let raw_x = raw_x.ok_or_else(|| anyhow!("predict needs --x v1,v2,... (or --stats)"))?;
        let x = parse_x(&raw_x)?;
        let mut base = 0usize;
        for a in &addrs {
            let mut client = PredictClient::connect(a.as_str(), timeout)?;
            let tasks = client.stats()?.tasks as usize;
            if task < base + tasks {
                let (y, model_seq) = client.predict(task - base, &x)?;
                println!(
                    "task {task}: yhat = {y:.6}  (model seq {model_seq}, replica {a} local task {})",
                    task - base
                );
                return client.close();
            }
            base += tasks;
            client.close()?;
        }
        bail!("task {task} is beyond the fleet's {base} task(s)");
    }
    if want_stats {
        for a in &addrs {
            let mut client = PredictClient::connect(a.as_str(), timeout)?;
            print_replica_stats(a, &client.stats()?);
            client.close()?;
        }
        return Ok(());
    }
    let raw_x = raw_x.ok_or_else(|| anyhow!("predict needs --x v1,v2,... (or --stats)"))?;
    let x = parse_x(&raw_x)?;
    let mut client = PredictClient::connect(addrs[0].as_str(), timeout)?;
    let (y, model_seq) = client.predict(task, &x)?;
    println!("task {task}: yhat = {y:.6}  (model seq {model_seq})");
    client.close()
}

/// Parse the `--x v1,v2,...` feature vector.
fn parse_x(raw: &str) -> Result<Vec<f64>> {
    raw.split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<std::result::Result<Vec<f64>, _>>()
        .map_err(|e| anyhow!("--x expects comma-separated numbers: {e}"))
}

/// One replica's `--stats` frame, labeled by its address.
fn print_replica_stats(addr: &str, s: &amtl::serve::ReplicaStats) {
    println!("replica stats from {addr}:");
    println!(
        "  model: {} tasks x {} features, seq {} (lag {})",
        s.tasks,
        s.dim,
        s.model_seq,
        s.lag()
    );
    println!(
        "  feed:  {} wal entries applied, {} bootstraps, {} hot-swaps",
        s.applied_entries, s.bootstraps, s.hot_swaps
    );
    println!(
        "  load:  {} predictions, {} errors, p50 {}us p99 {}us max {}us, up {}ms",
        s.predictions, s.errors, s.p50_us, s.p99_us, s.max_us, s.uptime_ms
    );
}

/// `top --connect <addr>`: poll `FetchMetrics` on a trainer (`--serve`)
/// or replica endpoint and render a live dashboard — updates/sec, commit
/// staleness quantiles, per-layer latency histograms, and every counter
/// and gauge the process registered. `--once` prints a single snapshot;
/// `--json` emits one machine-readable JSON object per poll instead;
/// `--fleet a,b,c` polls several endpoints at once and renders one
/// cluster-wide table (worker NODE rows fan in through the trainer).
fn cmd_top(opts: &Opts) -> Result<()> {
    let fleet_list = opts.get("fleet").map(|s| s.to_string());
    let connect = opts.get("connect").map(|s| s.to_string());
    let once = opts.flag("once");
    let json = opts.flag("json");
    let interval = Duration::from_millis(opts.get_u64("interval-ms", 1000)?.max(50));
    let timeout = Duration::from_millis(opts.get_u64("timeout-ms", 5000)?.max(1));
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;

    if let Some(list) = fleet_list {
        let addrs = split_addr_list(&list)?;
        return run_top_fleet(&addrs, once, json, interval, timeout);
    }
    let addr =
        connect.ok_or_else(|| anyhow!("top needs --connect <addr> or --fleet <a,b,...>"))?;

    // The predict client is just a framed request/response socket; both
    // the trainer and the replica answer FetchMetrics on it.
    let mut client = PredictClient::connect(addr.as_str(), timeout)?;
    let mut prev: Option<(std::time::Instant, u64)> = None;
    loop {
        let report = client.metrics()?;
        let now = std::time::Instant::now();
        let commits = report.counter("server.commits").unwrap_or(0);
        // Updates/sec from the commit delta between polls, through the
        // restart-guarded helper (a restarted endpoint re-zeroes its
        // counters; the rate must read 0, not a u64-underflow spike).
        // The first frame falls back to the process-lifetime average.
        let rate = match prev {
            Some((at, last)) => fleet::counter_delta(last, commits) as f64
                / now.duration_since(at).as_secs_f64().max(1e-9),
            None => commits as f64 / (report.uptime_ms as f64 / 1000.0).max(1e-9),
        };
        prev = Some((now, commits));
        if json {
            println!("{}", report_json(&report));
        } else {
            if !once {
                // ANSI clear + home: redraw in place like top(1).
                print!("\x1b[2J\x1b[H");
            }
            render_top(&addr, &report, rate);
        }
        if once {
            break;
        }
        std::thread::sleep(interval);
    }
    client.close()
}

/// Parse a `--fleet` comma-separated address list.
fn split_addr_list(list: &str) -> Result<Vec<String>> {
    let addrs: Vec<String> =
        list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    ensure!(!addrs.is_empty(), "--fleet expects a comma-separated address list");
    Ok(addrs)
}

/// One `FetchMetrics` round trip against `addr`; any connect, protocol,
/// or timeout failure reads as "endpoint down" (`None`) so the collector
/// records the miss instead of killing the dashboard.
fn fetch_report(addr: &str, timeout: Duration) -> Option<MetricsReport> {
    let mut client = PredictClient::connect(addr, timeout).ok()?;
    let report = client.metrics().ok();
    let _ = client.close();
    report
}

/// The `top --fleet` loop: poll every endpoint each interval, feed the
/// collector, render the flattened fleet table (or JSON).
fn run_top_fleet(
    addrs: &[String],
    once: bool,
    json: bool,
    interval: Duration,
    timeout: Duration,
) -> Result<()> {
    let mut collector = Collector::new(addrs);
    loop {
        collector.poll_with(amtl::obs::log::uptime_ms(), |a| fetch_report(a, timeout));
        if json {
            println!("{}", fleet_json(&collector));
        } else {
            if !once {
                print!("\x1b[2J\x1b[H");
            }
            render_fleet(&collector);
        }
        if once {
            break;
        }
        std::thread::sleep(interval);
    }
    Ok(())
}

/// One dashboard frame for `amtl top --fleet`: a row per endpoint plus a
/// row per fanned-in worker NODE report, then fleet-wide aggregates
/// merged across every row.
fn render_fleet(c: &Collector) {
    let rows = c.rows();
    let up = c.endpoints().iter().filter(|e| !e.down && !e.is_empty()).count();
    println!(
        "amtl top — fleet of {} endpoint(s), {up} up, {} row(s)",
        c.endpoints().len(),
        rows.len(),
    );
    println!(
        "{:<34} {:>8} {:>7} {:>9} {:>11} {:>11} {:>9}",
        "ENDPOINT", "ROLE", "SHARD", "UP(s)", "COMMITS", "STALE p99", "LAG"
    );
    for row in &rows {
        let r = row.report;
        let commits =
            r.counter("server.commits").map(|v| v.to_string()).unwrap_or_else(|| "-".into());
        let stale = r
            .hist("server.staleness")
            .map(|h| h.quantile(0.99).to_string())
            .unwrap_or_else(|| "-".into());
        let lag = r.gauge("replica.lag").map(|v| v.to_string()).unwrap_or_else(|| "-".into());
        // Sharded trainers export their partition slot as gauges
        // (`amtl --serve --shard i/N` sets both at startup).
        let shard = match (r.gauge("shard.index"), r.gauge("shard.count")) {
            (Some(i), Some(n)) if n > 0 => format!("{i}/{n}"),
            _ => "-".into(),
        };
        println!(
            "{:<34} {:>8} {:>7} {:>9.1} {:>11} {:>11} {:>9}",
            row.label(),
            r.role_name(),
            shard,
            r.uptime_ms as f64 / 1000.0,
            commits,
            stale,
            lag,
        );
    }
    for ep in c.endpoints() {
        if ep.down {
            println!(
                "{:<34} {:>8}   down ({} consecutive failed poll(s))",
                ep.addr, "-", ep.down_streak
            );
        }
    }
    // Window rate per trainer endpoint, summed (None until two samples).
    let rate: f64 =
        c.endpoints().iter().filter_map(|e| e.counter_window_rate("server.commits")).sum();
    println!("fleet updates/sec (window): {rate:.1}");
    if let Some(h) = c.merged_hist("commit_critical_path_us") {
        println!(
            "fleet commit critical path (us): p50 {}  p99 {}  max {}  ({} commits)",
            h.quantile(0.5),
            h.quantile(0.99),
            h.max,
            h.count(),
        );
    }
}

/// Machine-readable form of one fleet poll (`top --fleet --json`).
fn fleet_json(c: &Collector) -> String {
    let rows: Vec<Json> = c
        .rows()
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("endpoint", Json::Str(row.label())),
                ("report", report_json_value(row.report)),
            ])
        })
        .collect();
    let down: Vec<Json> =
        c.endpoints().iter().filter(|e| e.down).map(|e| Json::Str(e.addr.clone())).collect();
    Json::obj(vec![("rows", Json::Arr(rows)), ("down", Json::Arr(down))]).to_string()
}

/// `health --connect <addr>` / `health --fleet a,b,c`: poll each
/// endpoint a few times, evaluate the declarative health rule catalog
/// (staleness runaway, replica lag, eviction storm, updates/sec stall,
/// WAL fsync spike, endpoint down), print every violation, and exit
/// nonzero if any fired — the scriptable hook CI and the chaos harness
/// gate on. Thresholds are flags; the catalog with rationale lives in
/// docs/OBSERVABILITY.md.
fn cmd_health(opts: &Opts) -> Result<()> {
    let fleet_list = opts.get("fleet").map(|s| s.to_string());
    let connect = opts.get("connect").map(|s| s.to_string());
    let json = opts.flag("json");
    let interval = Duration::from_millis(opts.get_u64("interval-ms", 1000)?.max(50));
    let timeout = Duration::from_millis(opts.get_u64("timeout-ms", 5000)?.max(1));
    // Rate rules need an interval: two polls by default.
    let samples = opts.get_usize("samples", 2)?.max(1);
    let defaults = HealthRules::default();
    // `--lag-bound`/`--fsync-p99-us` are the documented names;
    // `--max-replica-lag`/`--wal-fsync-p99-us` predate them and stay
    // accepted. Both spellings are queried unconditionally so
    // reject_unknown never trips on either; the short name wins.
    let lag_short = opts.get("lag-bound").is_some();
    let lag_bound = opts.get_u64("lag-bound", defaults.max_replica_lag)?;
    let lag_legacy = opts.get_u64("max-replica-lag", defaults.max_replica_lag)?;
    let fsync_short = opts.get("fsync-p99-us").is_some();
    let fsync_bound = opts.get_u64("fsync-p99-us", defaults.wal_fsync_p99_us)?;
    let fsync_legacy = opts.get_u64("wal-fsync-p99-us", defaults.wal_fsync_p99_us)?;
    let rules = HealthRules {
        staleness_bound: match opts.get("staleness-bound") {
            Some(_) => Some(opts.get_u64("staleness-bound", 4)?),
            None => None,
        },
        max_replica_lag: if lag_short { lag_bound } else { lag_legacy },
        eviction_storm: opts.get_u64("eviction-storm", defaults.eviction_storm)?,
        min_updates_per_sec: opts.get_f64("min-rate", defaults.min_updates_per_sec)?,
        wal_fsync_p99_us: if fsync_short { fsync_bound } else { fsync_legacy },
    };
    opts.reject_unknown().map_err(|e| anyhow!("{e}"))?;
    let addrs = match (fleet_list, connect) {
        (Some(list), _) => split_addr_list(&list)?,
        (None, Some(addr)) => vec![addr],
        (None, None) => bail!("health needs --connect <addr> or --fleet <a,b,...>"),
    };

    let mut collector = Collector::new(&addrs);
    for i in 0..samples {
        if i > 0 {
            std::thread::sleep(interval);
        }
        collector.poll_with(amtl::obs::log::uptime_ms(), |a| fetch_report(a, timeout));
    }
    let violations = rules.evaluate(&collector);
    if json {
        let list: Vec<Json> = violations
            .iter()
            .map(|v| {
                Json::obj(vec![
                    ("rule", Json::Str(v.rule.to_string())),
                    ("endpoint", Json::Str(v.endpoint.clone())),
                    ("detail", Json::Str(v.detail.clone())),
                ])
            })
            .collect();
        println!(
            "{}",
            Json::obj(vec![
                ("healthy", Json::Bool(violations.is_empty())),
                ("endpoints", Json::Num(addrs.len() as f64)),
                ("violations", Json::Arr(list)),
            ])
        );
    } else if violations.is_empty() {
        println!("fleet healthy: {} endpoint(s), no rule fired", addrs.len());
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("{} violation(s)", violations.len());
    }
    if !violations.is_empty() {
        // Scriptable contract: nonzero exit on any violation. Output is
        // already line-flushed; no destructors matter past this point.
        std::process::exit(1);
    }
    Ok(())
}

/// One dashboard frame for `amtl top`.
fn render_top(addr: &str, r: &MetricsReport, updates_per_sec: f64) {
    println!(
        "amtl top — {} @ {addr}  up {:.1}s  updates/sec {updates_per_sec:.1}",
        r.role_name(),
        r.uptime_ms as f64 / 1000.0,
    );
    if let Some(h) = r.hist("server.staleness") {
        println!(
            "staleness (versions): p50 {}  p99 {}  max {}  mean {:.2}  ({} commits)",
            h.quantile(0.5),
            h.quantile(0.99),
            h.max,
            h.mean(),
            h.count(),
        );
    }
    if !r.hists.is_empty() {
        println!("histograms (count / p50 / p99 / max):");
        for (name, h) in &r.hists {
            if name == "server.staleness" {
                continue; // already summarized above
            }
            println!(
                "  {name:<28} {:>9} / {:>8} / {:>8} / {:>8}",
                h.count(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max,
            );
        }
    }
    if !r.counters.is_empty() {
        println!("counters:");
        for (name, v) in &r.counters {
            println!("  {name:<28} {v:>12}");
        }
    }
    if !r.gauges.is_empty() {
        println!("gauges:");
        for (name, v) in &r.gauges {
            println!("  {name:<28} {v:>12}");
        }
    }
    if !r.nodes.is_empty() {
        println!("nodes (fanned-in worker reports):");
        for (t, sub) in &r.nodes {
            let commit = sub
                .hist("node.commit_us")
                .map(|h| {
                    format!(
                        "commit p50 {}us p99 {}us ({} pushed)",
                        h.quantile(0.5),
                        h.quantile(0.99),
                        h.count(),
                    )
                })
                .unwrap_or_else(|| "no commits yet".into());
            println!(
                "  node {t:<3} up {:>7.1}s  {commit}",
                sub.uptime_ms as f64 / 1000.0,
            );
        }
    }
}

/// Machine-readable form of one metrics frame (`top --json`).
fn report_json(r: &MetricsReport) -> String {
    report_json_value(r).to_string()
}

/// The JSON value behind [`report_json`], reusable for fleet rows and
/// recursing (depth 1 — the wire format allows no deeper) into the
/// trainer's fanned-in worker NODE reports.
fn report_json_value(r: &MetricsReport) -> Json {
    let counters: Vec<(&str, Json)> =
        r.counters.iter().map(|(k, v)| (k.as_str(), Json::Num(*v as f64))).collect();
    let gauges: Vec<(&str, Json)> =
        r.gauges.iter().map(|(k, v)| (k.as_str(), Json::Num(*v as f64))).collect();
    let hists: Vec<(&str, Json)> = r
        .hists
        .iter()
        .map(|(k, h)| {
            (
                k.as_str(),
                Json::obj(vec![
                    ("count", Json::Num(h.count() as f64)),
                    ("mean", Json::Num(h.mean())),
                    ("p50", Json::Num(h.quantile(0.5) as f64)),
                    ("p99", Json::Num(h.quantile(0.99) as f64)),
                    ("max", Json::Num(h.max as f64)),
                ]),
            )
        })
        .collect();
    let nodes: Vec<Json> = r
        .nodes
        .iter()
        .map(|(t, sub)| {
            Json::obj(vec![
                ("node", Json::Num(*t as f64)),
                ("report", report_json_value(sub)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("role", Json::Str(r.role_name().to_string())),
        ("uptime_ms", Json::Num(r.uptime_ms as f64)),
        ("counters", Json::obj(counters)),
        ("gauges", Json::obj(gauges)),
        ("hists", Json::obj(hists)),
        ("nodes", Json::Arr(nodes)),
    ])
}

fn cmd_datasets(opts: &Opts) -> Result<()> {
    let mut rng = Rng::new(opts.get_u64("seed", 7)?);
    println!("Table II — simulated public datasets:");
    for name in ["school", "mnist", "mtfl"] {
        let ds = public::by_name(name, &mut rng).unwrap();
        println!("  {}", ds.describe());
    }
    Ok(())
}

fn cmd_artifacts(opts: &Opts) -> Result<()> {
    let dir = opts.get_or("artifacts-dir", "artifacts");
    let m = amtl::runtime::Manifest::load(std::path::Path::new(&dir))?;
    println!(
        "manifest OK: {} artifacts in {dir} (tile_n={})",
        m.len(),
        m.tile_n
    );
    for key in m.keys() {
        println!("  {key}");
    }
    Ok(())
}
