//! Fig. 4 — convergence of AMTL vs SMTL under the same network
//! configuration, for synthetic datasets with 5 and 10 tasks.
//!
//! Paper shape: objective vs iteration count; "AMTL is not only more time
//! efficient than SMTL, it also tends to converge faster than SMTL in terms
//! of the number of iterations as well."
//!
//! We print the objective trajectory (per global update count, normalized
//! to per-node epochs) for both methods, plus wall-clock — both axes of the
//! paper's claim.
//!
//! Run: `cargo bench --bench fig4_convergence [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;
    println!("engine: {engine:?}");
    let mut log = BenchLog::new("fig4_convergence");

    for &t in if quick { &[5usize][..] } else { &[5usize, 10][..] } {
        banner(
            &format!("Fig 4 — convergence, {t} tasks (objective vs epoch)"),
            "AMTL converges at least as fast as SMTL per iteration, and much faster in time",
        );
        let mut rng = Rng::new(42);
        let ds = synthetic::lowrank_regression(&vec![100; t], 50, 3, 0.5, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        let iters = if quick { 10 } else { 30 };
        let cfg = ExpConfig {
            iters,
            svd,
            offset_units: 1.0,
            record_every: t as u64, // one sample per "epoch" of T updates
            ..Default::default()
        };
        amtl::experiments::warm(&problem, engine, pool.as_ref())?;
        let a = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
        let s = run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?;

        let objs_a = a.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));
        let objs_s = s.compute_objectives(|w| problem.objective(w), |v| problem.prox_map(v));

        let mut table = Table::new(&["epoch", "AMTL F", "AMTL t(s)", "SMTL F", "SMTL t(s)"]);
        let rows = objs_a.len().max(objs_s.len());
        for i in 0..rows {
            let fmt = |o: Option<&(f64, u64, f64)>| match o {
                Some((secs, _, f)) => (format!("{f:.4}"), format!("{secs:.3}")),
                None => ("".into(), "".into()),
            };
            let (fa, ta) = fmt(objs_a.get(i));
            let (fs, ts) = fmt(objs_s.get(i));
            table.row(vec![i.to_string(), fa, ta, fs, ts]);
        }
        table.print();
        let last_a = objs_a.last().unwrap().2;
        let last_s = objs_s.last().unwrap().2;
        log.record_run(&format!("t{t}_amtl"), &a, last_a);
        log.record_run(&format!("t{t}_smtl"), &s, last_s);
        println!(
            "final: AMTL F={last_a:.4} in {:.2}s | SMTL F={last_s:.4} in {:.2}s | AMTL/SMTL time {:.2}x",
            a.wall_time.as_secs_f64(),
            s.wall_time.as_secs_f64(),
            a.wall_time.as_secs_f64() / s.wall_time.as_secs_f64().max(1e-12),
        );
    }
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
