//! Tables II & III — training time of AMTL vs SMTL on the (simulated)
//! public datasets under delay offsets 1/2/3 paper-seconds.
//!
//! Paper numbers (seconds):
//!
//! | Network | School | MNIST  | MTFL   |
//! | AMTL-1  | 194.22 |  54.96 |  50.40 |
//! | AMTL-2  | 231.58 |  83.17 |  77.44 |
//! | AMTL-3  | 460.15 | 115.46 | 103.45 |
//! | SMTL-1  | 299.79 |  57.94 |  50.59 |
//! | SMTL-2  | 298.42 | 114.85 |  92.84 |
//! | SMTL-3  | 593.36 | 161.67 | 146.87 |
//!
//! Expected shape: AMTL ≤ SMTL everywhere; the gap is widest for School
//! (139 tasks — the barrier pays the slowest of 139 draws) and narrow for
//! MTFL (4 tasks). The datasets are simulated equivalents matching Table II
//! exactly in (T, n-range, d, loss) — see `data::public`.
//!
//! Run: `cargo bench --bench table3_public [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Synchronized};
use amtl::data::public;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;

    banner("Table II — dataset descriptions", "matched to the paper's Table II");
    let mut rng = Rng::new(42);
    let names: &[&str] = if quick { &["mtfl"] } else { &["school", "mnist", "mtfl"] };
    for name in names {
        let ds = public::by_name(name, &mut rng).unwrap();
        println!("  {}", ds.describe());
    }

    banner(
        "Table III — training time on public datasets",
        "AMTL ≤ SMTL for every dataset and offset; gap widest for School (T=139)",
    );
    println!("engine: {engine:?}; 1 paper-second = 10 ms (divide paper numbers by 100)");

    let offsets: &[f64] = if quick { &[1.0] } else { &[1.0, 2.0, 3.0] };
    let iters = if quick { 2 } else { 10 };
    let mut log = BenchLog::new("table3_public");

    let mut table = Table::new(
        &std::iter::once("Network")
            .chain(names.iter().copied())
            .collect::<Vec<_>>(),
    );
    for method in ["AMTL", "SMTL"] {
        for &off in offsets {
            let mut cells = vec![format!("{method}-{off:.0}")];
            for name in names {
                let mut rng = Rng::new(42);
                let ds = public::by_name(name, &mut rng).unwrap();
                let t_count = ds.t();
                let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
                let cfg = ExpConfig {
                    iters,
                    offset_units: off,
                    svd,
                    // Keep the backward step off the critical path for the
                    // 139-task School run (§III.C allows batched proxes).
                    prox_every: (t_count as u64 / 4).max(1),
                    ..Default::default()
                };
                amtl::experiments::warm(&problem, engine, pool.as_ref())?;
                let r = if method == "AMTL" {
                    run_once(&problem, engine, pool.as_ref(), &cfg, Async)?
                } else {
                    run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?
                };
                log.record_run(
                    &format!("{method}-{off:.0}_{name}"),
                    &r,
                    problem.objective(&r.w_final),
                );
                cells.push(format!("{:.2}", r.wall_time.as_secs_f64()));
            }
            table.row(cells);
        }
    }
    table.print();
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
