//! Table I — computation times of AMTL and SMTL under different network
//! characteristics (delay offsets 5/10/30 paper-seconds) for T ∈ {5,10,15}.
//!
//! Paper numbers (seconds; 100 samples/task, d=50, nuclear norm):
//!
//! | Network  | 5 Tasks | 10 Tasks | 15 Tasks |
//! | AMTL-5   |  156.21 |   172.59 |   173.38 |
//! | AMTL-10  |  297.34 |   308.55 |   313.54 |
//! | AMTL-30  |  902.22 |   910.39 |   880.63 |
//! | SMTL-5   |  239.34 |   248.23 |   256.94 |
//! | SMTL-10  |  452.84 |   470.79 |   494.13 |
//! | SMTL-30  | 1238.16 |  1367.38 |  1454.57 |
//!
//! Expected shape: AMTL beats SMTL at every offset/T; AMTL is ~flat in T
//! while SMTL grows with T; both scale ~linearly with the offset. We scale
//! one paper-second to 10 ms (×100 compression), so e.g. AMTL-5 ≈ 1.5 s
//! here ↔ 156 s in the paper.
//!
//! Run: `cargo bench --bench table1_network [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;
    banner(
        "Table I — AMTL vs SMTL under different network delays",
        "AMTL wins everywhere; SMTL degrades as T grows (barrier on stragglers)",
    );
    println!("engine: {engine:?}; 1 paper-second = 10 ms (divide paper numbers by 100)");

    let offsets: &[f64] = if quick { &[5.0] } else { &[5.0, 10.0, 30.0] };
    let tasks: &[usize] = if quick { &[5] } else { &[5, 10, 15] };
    let iters = if quick { 3 } else { 10 };

    let mut log = BenchLog::new("table1_network");
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for method in ["AMTL", "SMTL"] {
        for &off in offsets {
            let mut cells = Vec::new();
            for &t in tasks {
                let mut rng = Rng::new(42);
                let ds = synthetic::random_regression(t, 100, 50, &mut rng);
                let problem =
                    MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
                let cfg = ExpConfig { iters, offset_units: off, svd, ..Default::default() };
                amtl::experiments::warm(&problem, engine, pool.as_ref())?;
                let r = if method == "AMTL" {
                    run_once(&problem, engine, pool.as_ref(), &cfg, Async)?
                } else {
                    run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?
                };
                log.record_run(
                    &format!("{method}-{off:.0}_t{t}"),
                    &r,
                    problem.objective(&r.w_final),
                );
                cells.push(r.wall_time.as_secs_f64());
            }
            rows.push((format!("{method}-{off:.0}"), cells));
        }
    }

    let headers: Vec<String> = std::iter::once("Network".to_string())
        .chain(tasks.iter().map(|t| format!("{t} Tasks (s)")))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for (name, cells) in &rows {
        table.row(
            std::iter::once(name.clone())
                .chain(cells.iter().map(|c| format!("{c:.2}")))
                .collect(),
        );
    }
    table.print();

    // Shape check (who wins), printed for the bench log.
    let n_off = offsets.len();
    let mut holds = true;
    for i in 0..n_off {
        let (amtl, smtl) = (&rows[i].1, &rows[i + n_off].1);
        for (a, s) in amtl.iter().zip(smtl) {
            if a >= s {
                holds = false;
            }
        }
    }
    println!("shape check — AMTL faster than SMTL in every cell: {holds}");
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
