//! §Perf harness — the performance baseline of record (see
//! `docs/PERFORMANCE.md` for the recorded numbers and the schema).
//!
//! Sections:
//!
//! 1. forward-step latency of the PJRT artifacts across shape buckets;
//! 2. backward-step (nuclear prox) per-op cost: full Jacobi SVT vs Brand
//!    online update + SVT;
//! 3. parallel linalg kernels: blocked matmul/gram on the worker pool vs
//!    the serial loop (same bits, different wall-clock);
//! 4. **end-to-end server throughput** (the acceptance metric): an
//!    asynchronous nuclear-norm session with zero injected delay, driven
//!    once with `--svd exact` semantics and once with the incremental
//!    default — `updates_per_sec` for both lands in
//!    `BENCH_perf_step.json`, so a single run records the before/after;
//! 5. **per-formulation throughput**: an async run per registered
//!    coupling (nuclear, ℓ2,1, elastic net, graph, mean) —
//!    `throughput_reg_<name>` records cover every server prox path the
//!    open formulation API ships;
//! 6. durability overhead: the same throughput run with checkpointing on
//!    (WAL fsync per commit + snapshot rotations), recorded as
//!    `throughput_checkpointed` / `durability_overhead`;
//! 7. observability overhead: the same throughput run with the JSONL
//!    trace writer attached (every activation/commit/prox traced),
//!    recorded as `throughput_instrumented` / `instrumentation_overhead`
//!    — the acceptance bar is instrumented ≥ 0.95x of plain;
//! 8. sharded server throughput: the same separable (ℓ1) run over 1, 2
//!    and 4 column-partitioned prox shards — the 2-shard number lands in
//!    `BENCH_perf_step.json` as `throughput_sharded`.
//!
//! Point `AMTL_ARTIFACTS` at an alternative artifact directory to A/B
//! kernel variants. `--threads N` sizes the linalg pool for section 3/4.
//!
//! Run: `cargo bench --bench perf_step [-- --threads 4]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, run_once, BenchLog, ExpConfig, Table};
use amtl::linalg::Mat;
use amtl::linalg::par;
use amtl::optim::prox::RegularizerKind;
use amtl::optim::svd::{OnlineSvd, Svd, SvdMode};
use amtl::runtime::WorkerPool;
use amtl::util::stats::bench_secs;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    // Shared bench flags (--threads / --svd); the returned mode is unused
    // because the throughput section sweeps both backends explicitly.
    let _ = amtl::experiments::bench_flags(&opts)?;
    let (engine, pool) = auto_engine(1);
    println!(
        "engine: {engine:?} (artifacts: {:?})",
        amtl::runtime::manifest::default_dir()
    );
    let mut log = BenchLog::new("perf_step");

    // ---- L2/L1: forward-step latency per bucket -------------------------
    println!("\n=== forward-step latency (PJRT artifact, per call) ===");
    let shapes: &[(&str, usize, usize)] = if quick {
        &[("lsq", 100, 50)]
    } else {
        &[
            ("lsq", 100, 50),
            ("lsq", 1000, 50),
            ("lsq", 10000, 50),
            ("lsq", 100, 400),
            ("logistic", 14000, 100),
            ("logistic", 10000, 10),
        ]
    };
    let mut table = Table::new(&["loss", "n", "d", "bucket", "mean ms", "min ms"]);
    for &(loss, n, d) in shapes {
        let mut rng = Rng::new(1);
        let ds = if loss == "lsq" {
            synthetic::lowrank_regression(&[n], d, 2.min(d), 0.1, &mut rng)
        } else {
            synthetic::lowrank_classification(&[n], d, 2.min(d), &mut rng)
        };
        let problem = MtlProblem::new(ds, RegularizerKind::None, 0.0, 0.5, &mut rng);
        let mut computes = problem.build_computes(engine, pool.as_ref())?;
        let w = rng.normal_vec(d);
        let bucket = format!("n{}", problem.dataset.tasks[0].n().next_power_of_two().max(128));
        let reps = if quick { 3 } else { 10 };
        let s = bench_secs(2, reps, || {
            let _ = computes[0].step(&w, 1e-4).unwrap();
        });
        log.record_kv(
            &format!("forward_{loss}_n{n}_d{d}"),
            &[("mean_ms", s.mean * 1e3), ("min_ms", s.min * 1e3)],
        );
        table.row(vec![
            loss.into(),
            n.to_string(),
            d.to_string(),
            bucket,
            format!("{:.2}", s.mean * 1e3),
            format!("{:.2}", s.min * 1e3),
        ]);
    }
    table.print();

    // ---- L3: backward-step (nuclear prox) per-op cost -------------------
    println!("\n=== backward-step cost: full Jacobi SVT vs online SVD (per prox) ===");
    let mut table = Table::new(&["d", "T", "full SVT ms", "online update+SVT ms"]);
    let dims: &[(usize, usize)] =
        if quick { &[(50, 10)] } else { &[(28, 139), (50, 15), (50, 100), (400, 5)] };
    for &(d, t) in dims {
        let mut rng = Rng::new(2);
        let m = Mat::randn(d, t, &mut rng);
        let reps = if quick { 3 } else { 10 };
        let full = bench_secs(1, reps, || {
            let _ = Svd::jacobi(&m).shrink_reconstruct(0.1);
        });
        let mut osvd = OnlineSvd::init(&m);
        let mut col_rng = Rng::new(3);
        let online = bench_secs(1, reps, || {
            let col = col_rng.normal_vec(d);
            osvd.replace_column(0, &col);
            let _ = osvd.shrink_reconstruct(0.1);
        });
        log.record_kv(
            &format!("prox_d{d}_t{t}"),
            &[("full_svt_ms", full.mean * 1e3), ("online_svt_ms", online.mean * 1e3)],
        );
        table.row(vec![
            d.to_string(),
            t.to_string(),
            format!("{:.3}", full.mean * 1e3),
            format!("{:.3}", online.mean * 1e3),
        ]);
    }
    table.print();

    // ---- linalg kernels: serial vs pool ---------------------------------
    println!("\n=== blocked linalg kernels: serial vs worker pool (bitwise-identical) ===");
    let kernel_pool = WorkerPool::new(amtl::linalg::threads().max(2));
    let mut table = Table::new(&["kernel", "shape", "serial ms", "pool ms", "speedup"]);
    let mm_shapes: &[(usize, usize, usize)] =
        if quick {
            &[(128, 64, 128)]
        } else {
            &[(256, 128, 256), (512, 256, 512), (400, 400, 139)]
        };
    for &(m, k, n) in mm_shapes {
        let mut rng = Rng::new(4);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let reps = if quick { 3 } else { 8 };
        let serial = bench_secs(1, reps, || {
            let _ = par::matmul_serial(&a, &b);
        });
        let pooled = bench_secs(1, reps, || {
            let _ = par::matmul_on(Some(&kernel_pool), &a, &b);
        });
        log.record_kv(
            &format!("matmul_{m}x{k}x{n}"),
            &[
                ("serial_ms", serial.mean * 1e3),
                ("pool_ms", pooled.mean * 1e3),
                ("threads", kernel_pool.threads() as f64),
            ],
        );
        table.row(vec![
            "matmul".into(),
            format!("{m}x{k}x{n}"),
            format!("{:.2}", serial.mean * 1e3),
            format!("{:.2}", pooled.mean * 1e3),
            format!("{:.2}x", serial.mean / pooled.mean.max(1e-12)),
        ]);
    }
    let gram_shapes: &[(usize, usize)] =
        if quick { &[(256, 64)] } else { &[(1024, 128), (4096, 64)] };
    for &(m, n) in gram_shapes {
        let mut rng = Rng::new(5);
        let a = Mat::randn(m, n, &mut rng);
        let reps = if quick { 3 } else { 8 };
        let serial = bench_secs(1, reps, || {
            let _ = par::gram_serial(&a);
        });
        let pooled = bench_secs(1, reps, || {
            let _ = par::gram_on(Some(&kernel_pool), &a);
        });
        log.record_kv(
            &format!("gram_{m}x{n}"),
            &[
                ("serial_ms", serial.mean * 1e3),
                ("pool_ms", pooled.mean * 1e3),
                ("threads", kernel_pool.threads() as f64),
            ],
        );
        table.row(vec![
            "gram".into(),
            format!("{m}x{n}"),
            format!("{:.2}", serial.mean * 1e3),
            format!("{:.2}", pooled.mean * 1e3),
            format!("{:.2}x", serial.mean / pooled.mean.max(1e-12)),
        ]);
    }
    table.print();

    // ---- end-to-end server throughput (the acceptance metric) -----------
    println!("\n=== server throughput: exact Jacobi vs incremental prox (updates/sec) ===");
    let (t_count, n, d, iters) = if quick { (6, 30, 20, 5) } else { (50, 100, 100, 20) };
    let mut results = Vec::new();
    for mode in [SvdMode::Exact, SvdMode::Online] {
        let mut rng = Rng::new(6);
        let ds = synthetic::lowrank_regression(&vec![n; t_count], d, 3, 0.5, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
        amtl::experiments::warm(&problem, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters,
            offset_units: 0.0, // no injected delay: measure the server, not the network
            svd: mode,
            ..Default::default()
        };
        let r = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
        let ups = r.updates as f64 / r.wall_time.as_secs_f64().max(1e-12);
        let label = format!("throughput_svd_{}", mode.name());
        log.record_run(&label, &r, problem.objective(&r.w_final));
        println!(
            "  svd={:<6} {:8.1} updates/sec  (wall {:.2}s, prox {}, coalesced {}, refreshes {})",
            mode.name(),
            ups,
            r.wall_time.as_secs_f64(),
            r.prox_count,
            r.coalesced_updates,
            r.svd_refreshes,
        );
        results.push(ups);
    }
    let speedup = results[1] / results[0].max(1e-12);
    log.record_kv(
        "throughput_speedup",
        &[
            ("online_over_exact", speedup),
            ("threads", amtl::linalg::threads() as f64),
        ],
    );
    println!("  online/exact speedup: {speedup:.2}x (threads={})", amtl::linalg::threads());

    // ---- per-formulation server throughput (the open formulation API) ---
    println!("\n=== per-formulation server throughput (updates/sec, async, no delay) ===");
    {
        let (ft, fn_, fd, fiters) = if quick { (4, 20, 10, 3) } else { (20, 60, 40, 10) };
        let mut table = Table::new(&["formulation", "updates/sec", "objective", "prox"]);
        for spec_str in ["nuclear", "l21", "elasticnet", "graph:topology=ring,weight=0.5", "mean"]
        {
            let spec = amtl::optim::FormulationSpec::parse(spec_str)?;
            let name = spec.name();
            let mut rng = Rng::new(8);
            let ds = synthetic::lowrank_regression(&vec![fn_; ft], fd, 3, 0.5, &mut rng);
            let problem = MtlProblem::try_new(ds, spec, 0.3, 0.5, &mut rng)?;
            amtl::experiments::warm(&problem, engine, pool.as_ref())?;
            let cfg = ExpConfig { iters: fiters, offset_units: 0.0, ..Default::default() };
            let r = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
            let ups = r.updates as f64 / r.wall_time.as_secs_f64().max(1e-12);
            log.record_run(&format!("throughput_reg_{name}"), &r, problem.objective(&r.w_final));
            table.row(vec![
                name.to_string(),
                format!("{ups:.1}"),
                format!("{:.4}", problem.objective(&r.w_final)),
                r.prox_count.to_string(),
            ]);
        }
        table.print();
    }

    // ---- sharded server throughput: N prox shards vs one server ---------
    println!("\n=== sharded server: commit throughput vs shard count (updates/sec, l1) ===");
    {
        use amtl::shard::{run_sharded, ShardRunConfig};
        let (st, sn, sd, siters) = if quick { (6, 20, 10, 4) } else { (24, 60, 40, 15) };
        let mut rng = Rng::new(9);
        let ds = synthetic::lowrank_regression(&vec![sn; st], sd, 3, 0.5, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::L1, 0.3, 0.5, &mut rng);
        let mut table = Table::new(&["shards", "updates/sec", "vs 1 shard", "objective"]);
        let mut single_ups = 0.0f64;
        for shards in [1usize, 2, 4] {
            if shards > st {
                continue;
            }
            let cfg = ShardRunConfig::new(shards, siters, 0.5, 9);
            let start = std::time::Instant::now();
            let res = run_sharded(&problem, &cfg)?;
            let wall = start.elapsed().as_secs_f64().max(1e-12);
            let ups = res.updates as f64 / wall;
            if shards == 1 {
                single_ups = ups;
            }
            if shards == 2 {
                // The gated record: the 2-shard separable path must keep
                // commit throughput in the same league as one server.
                log.record_kv(
                    "throughput_sharded",
                    &[
                        ("updates_per_sec", ups),
                        ("sharded_over_single", ups / single_ups.max(1e-12)),
                        ("shards", shards as f64),
                    ],
                );
            }
            table.row(vec![
                shards.to_string(),
                format!("{ups:.1}"),
                format!("{:.2}x", ups / single_ups.max(1e-12)),
                format!("{:.4}", res.objective),
            ]);
        }
        table.print();
    }

    // ---- durability overhead: same run with the WAL + snapshots on ------
    println!("\n=== durability: checkpointed run (WAL fsync per commit + snapshots) ===");
    {
        let mut rng = Rng::new(6);
        let ds = synthetic::lowrank_regression(&vec![n; t_count], d, 3, 0.5, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
        amtl::experiments::warm(&problem, engine, pool.as_ref())?;
        let cfg = ExpConfig { iters, offset_units: 0.0, ..Default::default() };
        let dir = std::env::temp_dir().join(format!("amtl_bench_ckpt_{}", std::process::id()));
        let r = amtl::coordinator::Session::builder(&problem)
            .engine(engine)
            .pool(pool.as_ref())
            .config(cfg.run_config())
            .checkpoint_dir(Some(dir.clone()))
            .checkpoint_every(64)
            .schedule(Async)
            .build()?
            .run()?;
        let ups = r.updates as f64 / r.wall_time.as_secs_f64().max(1e-12);
        log.record_run("throughput_checkpointed", &r, problem.objective(&r.w_final));
        log.record_kv(
            "durability_overhead",
            &[
                ("updates_per_sec", ups),
                ("durable_over_plain", ups / results[1].max(1e-12)),
                ("checkpoints_written", r.checkpoints_written as f64),
            ],
        );
        println!(
            "  checkpointed {:8.1} updates/sec  ({:.2}x of the online baseline, {} snapshots)",
            ups,
            ups / results[1].max(1e-12),
            r.checkpoints_written,
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- observability overhead: same run with the JSONL trace on -------
    println!("\n=== observability: traced run (JSONL event per activation/commit/prox) ===");
    {
        let mut rng = Rng::new(6);
        let ds = synthetic::lowrank_regression(&vec![n; t_count], d, 3, 0.5, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
        amtl::experiments::warm(&problem, engine, pool.as_ref())?;
        let cfg = ExpConfig { iters, offset_units: 0.0, ..Default::default() };
        let path =
            std::env::temp_dir().join(format!("amtl_bench_trace_{}.jsonl", std::process::id()));
        let trace = std::sync::Arc::new(amtl::obs::TraceWriter::create(&path)?);
        let r = amtl::coordinator::Session::builder(&problem)
            .engine(engine)
            .pool(pool.as_ref())
            .config(cfg.run_config())
            .trace(Some(std::sync::Arc::clone(&trace)))
            .schedule(Async)
            .build()?
            .run()?;
        trace.flush();
        let ups = r.updates as f64 / r.wall_time.as_secs_f64().max(1e-12);
        let over = ups / results[1].max(1e-12);
        log.record_run("throughput_instrumented", &r, problem.objective(&r.w_final));
        log.record_kv(
            "instrumentation_overhead",
            &[
                ("updates_per_sec", ups),
                ("instrumented_over_plain", over),
                ("mean_staleness", r.mean_staleness),
            ],
        );
        println!(
            "  instrumented {:8.1} updates/sec  ({:.2}x of the online baseline, staleness mean {:.2})",
            ups, over, r.mean_staleness,
        );
        std::fs::remove_file(&path).ok();
    }

    println!("bench records: {}", log.write()?.display());
    Ok(())
}
