//! §Perf harness — per-step latency of the PJRT forward-step artifacts
//! across shape buckets, plus the server-side backward-step (prox) cost
//! for full Jacobi SVD vs Brand online SVD.
//!
//! This is the measurement tool of the performance pass (EXPERIMENTS.md
//! §Perf). Point `AMTL_ARTIFACTS` at an alternative artifact directory to
//! A/B kernel variants (e.g. fixed- vs adaptive-tile lowering).
//!
//! Run: `cargo bench --bench perf_step`

use amtl::coordinator::MtlProblem;
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, BenchLog, Table};
use amtl::linalg::Mat;
use amtl::optim::prox::RegularizerKind;
use amtl::optim::svd::{OnlineSvd, Svd};
use amtl::util::stats::bench_secs;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?} (artifacts: {:?})", amtl::runtime::manifest::default_dir());
    let mut log = BenchLog::new("perf_step");

    // ---- L2/L1: forward-step latency per bucket -------------------------
    println!("\n=== forward-step latency (PJRT artifact, per call) ===");
    let shapes: &[(&str, usize, usize)] = if quick {
        &[("lsq", 100, 50)]
    } else {
        &[
            ("lsq", 100, 50),
            ("lsq", 1000, 50),
            ("lsq", 10000, 50),
            ("lsq", 100, 400),
            ("logistic", 14000, 100),
            ("logistic", 10000, 10),
        ]
    };
    let mut table = Table::new(&["loss", "n", "d", "bucket", "mean ms", "min ms"]);
    for &(loss, n, d) in shapes {
        let mut rng = Rng::new(1);
        let ds = if loss == "lsq" {
            synthetic::lowrank_regression(&[n], d, 2.min(d), 0.1, &mut rng)
        } else {
            synthetic::lowrank_classification(&[n], d, 2.min(d), &mut rng)
        };
        let problem = MtlProblem::new(ds, RegularizerKind::None, 0.0, 0.5, &mut rng);
        let mut computes = problem.build_computes(engine, pool.as_ref())?;
        let w = rng.normal_vec(d);
        let bucket = format!("n{}", problem.dataset.tasks[0].n().next_power_of_two().max(128));
        let reps = if quick { 3 } else { 10 };
        let s = bench_secs(2, reps, || {
            let _ = computes[0].step(&w, 1e-4).unwrap();
        });
        log.record_kv(
            &format!("forward_{loss}_n{n}_d{d}"),
            &[("mean_ms", s.mean * 1e3), ("min_ms", s.min * 1e3)],
        );
        table.row(vec![
            loss.into(),
            n.to_string(),
            d.to_string(),
            bucket,
            format!("{:.2}", s.mean * 1e3),
            format!("{:.2}", s.min * 1e3),
        ]);
    }
    table.print();

    // ---- L3: backward-step (nuclear prox) cost --------------------------
    println!("\n=== backward-step cost: full Jacobi SVT vs online SVD (per prox) ===");
    let mut table = Table::new(&["d", "T", "full SVT ms", "online update+SVT ms"]);
    let dims: &[(usize, usize)] = if quick { &[(50, 10)] } else { &[(28, 139), (50, 15), (50, 100), (400, 5)] };
    for &(d, t) in dims {
        let mut rng = Rng::new(2);
        let m = Mat::randn(d, t, &mut rng);
        let reps = if quick { 3 } else { 10 };
        let full = bench_secs(1, reps, || {
            let _ = Svd::jacobi(&m).shrink_reconstruct(0.1);
        });
        let mut osvd = OnlineSvd::init(&m);
        let mut col_rng = Rng::new(3);
        let online = bench_secs(1, reps, || {
            let col = col_rng.normal_vec(d);
            osvd.replace_column(0, &col);
            let _ = osvd.shrink_reconstruct(0.1);
        });
        log.record_kv(
            &format!("prox_d{d}_t{t}"),
            &[("full_svt_ms", full.mean * 1e3), ("online_svt_ms", online.mean * 1e3)],
        );
        table.row(vec![
            d.to_string(),
            t.to_string(),
            format!("{:.3}", full.mean * 1e3),
            format!("{:.3}", online.mean * 1e3),
        ]);
    }
    table.print();
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
