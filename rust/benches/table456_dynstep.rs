//! Tables IV, V, VI — the dynamic step size (§III.D): final objective after
//! a fixed 10 iterations, with vs without the Eq. III.6 multiplier, for
//! T ∈ {5, 10, 15} and delay offsets {5, 10, 15, 20}.
//!
//! Paper shape (synthetic, 100 samples/task, d=50): the dynamic step size
//! always reaches a *lower* objective within the iteration budget, and its
//! advantage grows with the delay. E.g. Table IV (5 tasks):
//!
//! | Network  | fixed step | dynamic step |
//! | AMTL-5   |     163.62 |       144.83 |
//! | AMTL-20  |     168.63 |       143.50 |
//!
//! The paper averages the last 5 delays (ν̄) per node; so do we. Delays are
//! recorded in paper units, so the multiplier log(max(ν̄, 10)) sees the
//! same numbers as the paper despite wall-clock scaling.
//!
//! Run: `cargo bench --bench table456_dynstep [-- 5|10|15] [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;
    println!("engine: {engine:?}");

    let selected: Vec<usize> = opts
        .positional
        .iter()
        .filter_map(|s| s.parse().ok())
        .collect();
    let tasks: Vec<usize> = if !selected.is_empty() {
        selected
    } else if quick {
        vec![5]
    } else {
        vec![5, 10, 15]
    };
    let offsets: &[f64] = if quick { &[5.0, 20.0] } else { &[5.0, 10.0, 15.0, 20.0] };
    let mut log = BenchLog::new("table456_dynstep");

    for (ti, t) in tasks.iter().enumerate() {
        let roman = ["IV", "V", "VI"].get(ti).copied().unwrap_or("–");
        banner(
            &format!("Table {roman} — dynamic step size, {t} tasks (final objective @ 10 iters)"),
            "dynamic step reaches a lower objective; the gap grows with the delay",
        );
        let mut table = Table::new(&["Network", "fixed step", "dynamic step", "improvement"]);
        for &off in offsets {
            let mut objs = [0.0f64; 2];
            for (i, dynamic) in [false, true].into_iter().enumerate() {
                let mut rng = Rng::new(42);
                let ds = synthetic::lowrank_regression(&vec![100; *t], 50, 3, 0.5, &mut rng);
                let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
                let cfg = ExpConfig {
                    iters: 10, // the paper's fixed budget
                    offset_units: off,
                    svd,
                    eta_k: 0.3, // dynamic multiplier stays in the stable range
                    dynamic_step: dynamic,
                    ..Default::default()
                };
                amtl::experiments::warm(&problem, engine, pool.as_ref())?;
                let r = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
                objs[i] = problem.objective(&r.w_final);
                let step = if dynamic { "dynamic" } else { "fixed" };
                log.record_run(&format!("t{t}_AMTL-{off:.0}_{step}"), &r, objs[i]);
            }
            table.row(vec![
                format!("AMTL-{off:.0}"),
                format!("{:.2}", objs[0]),
                format!("{:.2}", objs[1]),
                format!("{:+.1}%", 100.0 * (objs[1] - objs[0]) / objs[0]),
            ]);
        }
        table.print();
    }
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
