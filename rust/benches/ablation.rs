//! Ablations over the repo's load-bearing design choices:
//!
//! 1. **prox stride** (`prox_every`): how often the server recomputes the
//!    backward step. The paper (§III.C) notes the prox "can be applied
//!    after several gradient updates"; this quantifies the staleness ↔
//!    server-throughput trade-off.
//! 2. **online SVD vs full Jacobi** for the nuclear prox (§IV.A).
//! 3. **delay distribution** sensitivity: the ×100 time-compression claim
//!    — the AMTL/SMTL wall-clock ratio is stable across time
//!    scales.
//! 4. **update schedule**: async vs bounded-staleness vs synchronized
//!    under one network setting — the staleness bound sweeps between the
//!    paper's two extremes.
//!
//! Run: `cargo bench --bench ablation [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Schedule, SemiSync, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::optim::svd::SvdMode;
use amtl::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;
    println!("engine: {engine:?}");
    let mut log = BenchLog::new("ablation");

    // ---- 1. prox stride -------------------------------------------------
    banner(
        "Ablation — server prox stride (T=20, offset 2)",
        "staleness barely hurts the objective; large strides cut server SVD work",
    );
    let strides: &[u64] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16] };
    let mut table = Table::new(&["prox_every", "objective", "prox count", "wall (s)"]);
    for &pe in strides {
        let mut rng = Rng::new(11);
        let ds = synthetic::lowrank_regression(&[100; 20], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 4 } else { 15 },
            offset_units: 2.0,
            prox_every: pe,
            svd,
            ..Default::default()
        };
        let r = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        log.record_run(&format!("prox_every_{pe}"), &r, p.objective(&r.w_final));
        table.row(vec![
            pe.to_string(),
            format!("{:.2}", p.objective(&r.w_final)),
            r.prox_count.to_string(),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();

    // ---- 2. online SVD --------------------------------------------------
    banner(
        "Ablation — nuclear prox backend and refresh stride (T=40, d=50)",
        "online SVD cuts per-update cost at high T (§IV.A); exact refresh bounds drift",
    );
    let mut table = Table::new(&["backend", "resvd_every", "objective", "refreshes", "wall (s)"]);
    let variants: &[(SvdMode, u64)] = if quick {
        &[(SvdMode::Exact, 0), (SvdMode::Online, 64)]
    } else {
        &[
            (SvdMode::Exact, 0),
            (SvdMode::Online, 0),
            (SvdMode::Online, 16),
            (SvdMode::Online, 64),
            (SvdMode::Online, 256),
        ]
    };
    for &(mode, resvd_every) in variants {
        let mut rng = Rng::new(12);
        let t = if quick { 10 } else { 40 };
        let ds = synthetic::lowrank_regression(&vec![100; t], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 4 } else { 10 },
            offset_units: 1.0,
            svd: mode,
            resvd_every,
            ..Default::default()
        };
        let r = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        log.record_run(
            &format!("nuclear_{}_resvd{resvd_every}", mode.name()),
            &r,
            p.objective(&r.w_final),
        );
        table.row(vec![
            mode.name().into(),
            resvd_every.to_string(),
            format!("{:.2}", p.objective(&r.w_final)),
            r.svd_refreshes.to_string(),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();

    // ---- 3. time-scale sensitivity --------------------------------------
    banner(
        "Ablation — delay time-scale sensitivity (T=8, offset 5)",
        "the AMTL/SMTL ratio is stable under the x100 time compression",
    );
    let scales: &[u64] = if quick { &[5, 20] } else { &[2, 5, 10, 20, 50] };
    let mut table = Table::new(&["ms per paper-s", "AMTL (s)", "SMTL (s)", "ratio"]);
    for &ms in scales {
        let mut rng = Rng::new(13);
        let ds = synthetic::lowrank_regression(&[100; 8], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 3 } else { 8 },
            offset_units: 5.0,
            time_scale: Duration::from_millis(ms),
            svd,
            ..Default::default()
        };
        let a = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        let s = run_once(&p, engine, pool.as_ref(), &cfg, Synchronized)?;
        log.record_run(&format!("timescale_{ms}ms_amtl"), &a, p.objective(&a.w_final));
        log.record_run(&format!("timescale_{ms}ms_smtl"), &s, p.objective(&s.w_final));
        table.row(vec![
            ms.to_string(),
            format!("{:.2}", a.wall_time.as_secs_f64()),
            format!("{:.2}", s.wall_time.as_secs_f64()),
            format!("{:.2}x", s.wall_time.as_secs_f64() / a.wall_time.as_secs_f64().max(1e-12)),
        ]);
    }
    table.print();

    // ---- 4. update schedule ---------------------------------------------
    banner(
        "Ablation — update schedule (T=8, offset 3)",
        "bounded staleness interpolates between Algorithm 1 and the SMTL barrier",
    );
    let schedules: Vec<(String, Box<dyn Schedule>)> = vec![
        ("async".into(), Box::new(Async)),
        ("semisync-8".into(), Box::new(SemiSync { staleness_bound: 8 })),
        ("semisync-2".into(), Box::new(SemiSync { staleness_bound: 2 })),
        ("synchronized".into(), Box::new(Synchronized)),
    ];
    let mut table = Table::new(&["schedule", "objective", "wall (s)"]);
    let mut rng = Rng::new(14);
    let ds = synthetic::lowrank_regression(&[100; 8], 50, 3, 0.5, &mut rng);
    let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    amtl::experiments::warm(&p, engine, pool.as_ref())?;
    let cfg = ExpConfig {
        iters: if quick { 3 } else { 10 },
        offset_units: 3.0,
        svd,
        ..Default::default()
    };
    for (label, schedule) in schedules {
        let r = amtl::coordinator::Session::builder(&p)
            .engine(engine)
            .pool(pool.as_ref())
            .config(cfg.run_config())
            .schedule_box(schedule)
            .build()?
            .run()?;
        log.record_run(&format!("schedule_{label}"), &r, p.objective(&r.w_final));
        table.row(vec![
            label,
            format!("{:.2}", p.objective(&r.w_final)),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
