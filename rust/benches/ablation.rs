//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **prox stride** (`prox_every`): how often the server recomputes the
//!    backward step. The paper (§III.C) notes the prox "can be applied
//!    after several gradient updates"; this quantifies the staleness ↔
//!    server-throughput trade-off.
//! 2. **online SVD vs full Jacobi** for the nuclear prox (§IV.A).
//! 3. **delay distribution** sensitivity: the ×100 time-compression claim
//!    (DESIGN.md) — the AMTL/SMTL wall-clock ratio is stable across time
//!    scales.
//! 4. **update schedule**: async vs bounded-staleness vs synchronized
//!    under one network setting — the staleness bound sweeps between the
//!    paper's two extremes.
//!
//! Run: `cargo bench --bench ablation [-- --quick]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Schedule, SemiSync, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let (engine, pool) = auto_engine(1);
    println!("engine: {engine:?}");
    let mut log = BenchLog::new("ablation");

    // ---- 1. prox stride -------------------------------------------------
    banner(
        "Ablation — server prox stride (T=20, offset 2)",
        "staleness barely hurts the objective; large strides cut server SVD work",
    );
    let strides: &[u64] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16] };
    let mut table = Table::new(&["prox_every", "objective", "prox count", "wall (s)"]);
    for &pe in strides {
        let mut rng = Rng::new(11);
        let ds = synthetic::lowrank_regression(&[100; 20], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 4 } else { 15 },
            offset_units: 2.0,
            prox_every: pe,
            ..Default::default()
        };
        let r = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        log.record_run(&format!("prox_every_{pe}"), &r, p.objective(&r.w_final));
        table.row(vec![
            pe.to_string(),
            format!("{:.2}", p.objective(&r.w_final)),
            r.prox_count.to_string(),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();

    // ---- 2. online SVD --------------------------------------------------
    banner(
        "Ablation — nuclear prox backend (T=40, d=50)",
        "online SVD trades exactness for per-update cost at high T (§IV.A)",
    );
    let mut table = Table::new(&["backend", "objective", "wall (s)"]);
    for online in [false, true] {
        let mut rng = Rng::new(12);
        let t = if quick { 10 } else { 40 };
        let ds = synthetic::lowrank_regression(&vec![100; t], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 4 } else { 10 },
            offset_units: 1.0,
            online_svd: online,
            ..Default::default()
        };
        let r = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        let backend = if online { "online_svd" } else { "jacobi" };
        log.record_run(&format!("nuclear_{backend}"), &r, p.objective(&r.w_final));
        table.row(vec![
            if online { "online (Brand)" } else { "full Jacobi" }.into(),
            format!("{:.2}", p.objective(&r.w_final)),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();

    // ---- 3. time-scale sensitivity --------------------------------------
    banner(
        "Ablation — delay time-scale sensitivity (T=8, offset 5)",
        "the AMTL/SMTL ratio is stable under the x100 compression (DESIGN.md)",
    );
    let scales: &[u64] = if quick { &[5, 20] } else { &[2, 5, 10, 20, 50] };
    let mut table = Table::new(&["ms per paper-s", "AMTL (s)", "SMTL (s)", "ratio"]);
    for &ms in scales {
        let mut rng = Rng::new(13);
        let ds = synthetic::lowrank_regression(&[100; 8], 50, 3, 0.5, &mut rng);
        let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
        amtl::experiments::warm(&p, engine, pool.as_ref())?;
        let cfg = ExpConfig {
            iters: if quick { 3 } else { 8 },
            offset_units: 5.0,
            time_scale: Duration::from_millis(ms),
            ..Default::default()
        };
        let a = run_once(&p, engine, pool.as_ref(), &cfg, Async)?;
        let s = run_once(&p, engine, pool.as_ref(), &cfg, Synchronized)?;
        log.record_run(&format!("timescale_{ms}ms_amtl"), &a, p.objective(&a.w_final));
        log.record_run(&format!("timescale_{ms}ms_smtl"), &s, p.objective(&s.w_final));
        table.row(vec![
            ms.to_string(),
            format!("{:.2}", a.wall_time.as_secs_f64()),
            format!("{:.2}", s.wall_time.as_secs_f64()),
            format!("{:.2}x", s.wall_time.as_secs_f64() / a.wall_time.as_secs_f64().max(1e-12)),
        ]);
    }
    table.print();

    // ---- 4. update schedule ---------------------------------------------
    banner(
        "Ablation — update schedule (T=8, offset 3)",
        "bounded staleness interpolates between Algorithm 1 and the SMTL barrier",
    );
    let schedules: Vec<(String, Box<dyn Schedule>)> = vec![
        ("async".into(), Box::new(Async)),
        ("semisync-8".into(), Box::new(SemiSync { staleness_bound: 8 })),
        ("semisync-2".into(), Box::new(SemiSync { staleness_bound: 2 })),
        ("synchronized".into(), Box::new(Synchronized)),
    ];
    let mut table = Table::new(&["schedule", "objective", "wall (s)"]);
    let mut rng = Rng::new(14);
    let ds = synthetic::lowrank_regression(&[100; 8], 50, 3, 0.5, &mut rng);
    let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    amtl::experiments::warm(&p, engine, pool.as_ref())?;
    let cfg = ExpConfig {
        iters: if quick { 3 } else { 10 },
        offset_units: 3.0,
        ..Default::default()
    };
    for (label, schedule) in schedules {
        let r = amtl::coordinator::Session::builder(&p)
            .engine(engine)
            .pool(pool.as_ref())
            .config(cfg.run_config())
            .schedule_box(schedule)
            .build()?
            .run()?;
        log.record_run(&format!("schedule_{label}"), &r, p.objective(&r.w_final));
        table.row(vec![
            label,
            format!("{:.2}", p.objective(&r.w_final)),
            format!("{:.2}", r.wall_time.as_secs_f64()),
        ]);
    }
    table.print();
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
