//! Fig. 3 — computation time of AMTL vs SMTL for (a) varying number of
//! tasks, (b) varying sample sizes, (c) varying dimensionality.
//!
//! Paper setup (§IV.B.1): synthetic regression, nuclear-norm regularizer,
//! fixed number of iterations; (a) d=50, n=100; (b) T=5, d=50; (c) T=5,
//! n=100. Expected shape: SMTL needs more time than AMTL everywhere; the
//! gap grows with T (3a) and with d (3c); both are mostly flat in n until
//! the gradient cost bites (3b).
//!
//! Delay scaling: one paper-second = 10 ms here (the x100 compression);
//! the injected offset is 2 paper-units per activation — the distributed
//! setting always has communication delay, and it is what the barrier
//! amplifies.
//!
//! Run: `cargo bench --bench fig3_scaling [-- --quick] [-- fig3a|fig3b|fig3c]`

use amtl::config::Opts;
use amtl::coordinator::{Async, MtlProblem, Synchronized};
use amtl::data::synthetic;
use amtl::experiments::{auto_engine, banner, run_once, BenchLog, ExpConfig, Table};
use amtl::optim::prox::RegularizerKind;
use amtl::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let quick = opts.flag("quick") || std::env::var_os("AMTL_BENCH_QUICK").is_some();
    let which: Vec<&str> = opts
        .positional
        .iter()
        .map(|s| s.as_str())
        .filter(|s| s.starts_with("fig"))
        .collect();
    let all = which.is_empty();
    let (engine, pool) = auto_engine(1);
    let svd = amtl::experiments::bench_flags(&opts)?;
    println!("engine: {engine:?}  (1 paper-second = 10 ms)");
    let mut log = BenchLog::new("fig3_scaling");

    type RunArgs<'a> = (&'a str, usize, usize, usize, u64);
    let run = |log: &mut BenchLog, args: RunArgs| -> anyhow::Result<(f64, f64)> {
        let (label, t, n, d, prox_every) = args;
        let mut rng = Rng::new(42);
        let ds = synthetic::random_regression(t, n, d, &mut rng);
        let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng);
        let cfg = ExpConfig {
            iters: if quick { 3 } else { 10 },
            offset_units: 2.0,
            prox_every,
            svd,
            ..Default::default()
        };
        amtl::experiments::warm(&problem, engine, pool.as_ref())?;
        let a = run_once(&problem, engine, pool.as_ref(), &cfg, Async)?;
        let s = run_once(&problem, engine, pool.as_ref(), &cfg, Synchronized)?;
        log.record_run(&format!("{label}_amtl"), &a, problem.objective(&a.w_final));
        log.record_run(&format!("{label}_smtl"), &s, problem.objective(&s.w_final));
        Ok((a.wall_time.as_secs_f64(), s.wall_time.as_secs_f64()))
    };

    if all || which.contains(&"fig3a") {
        banner(
            "Fig 3a — time vs number of tasks (d=50, n=100)",
            "SMTL grows much faster with T than AMTL (barrier waits for all tasks)",
        );
        let ts: &[usize] = if quick { &[5, 10] } else { &[5, 10, 25, 50, 100] };
        let mut table = Table::new(&["T", "AMTL (s)", "SMTL (s)", "SMTL/AMTL"]);
        for &t in ts {
            // Paper's own mitigation for the backward-step pile-up at high
            // T: prox after several updates (§III.C); stride T/4.
            let (a, s) = run(&mut log, (&format!("fig3a_t{t}"), t, 100, 50, (t as u64 / 4).max(1)))?;
            table.row(vec![
                t.to_string(),
                format!("{a:.3}"),
                format!("{s:.3}"),
                format!("{:.2}x", s / a.max(1e-12)),
            ]);
        }
        table.print();
    }

    if all || which.contains(&"fig3b") {
        banner(
            "Fig 3b — time vs samples per task (T=5, d=50)",
            "no abrupt change with n; AMTL < SMTL throughout",
        );
        let ns: &[usize] = if quick { &[100, 1000] } else { &[100, 500, 1000, 5000, 10000] };
        let mut table = Table::new(&["n", "AMTL (s)", "SMTL (s)", "SMTL/AMTL"]);
        for &n in ns {
            let (a, s) = run(&mut log, (&format!("fig3b_n{n}"), 5, n, 50, 1))?;
            table.row(vec![
                n.to_string(),
                format!("{a:.3}"),
                format!("{s:.3}"),
                format!("{:.2}x", s / a.max(1e-12)),
            ]);
        }
        table.print();
    }

    if all || which.contains(&"fig3c") {
        banner(
            "Fig 3c — time vs dimensionality (T=5, n=100)",
            "time grows with d for both; the AMTL-SMTL gap widens",
        );
        let ds: &[usize] = if quick { &[10, 100] } else { &[10, 25, 50, 100, 200, 400] };
        let mut table = Table::new(&["d", "AMTL (s)", "SMTL (s)", "SMTL/AMTL"]);
        for &d in ds {
            let (a, s) = run(&mut log, (&format!("fig3c_d{d}"), 5, 100, d, 1))?;
            table.row(vec![
                d.to_string(),
                format!("{a:.3}"),
                format!("{s:.3}"),
                format!("{:.2}x", s / a.max(1e-12)),
            ]);
        }
        table.print();
    }
    println!("bench records: {}", log.write()?.display());
    Ok(())
}
