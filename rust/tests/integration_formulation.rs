//! Acceptance tests for the open formulation API (PR: trait-based
//! losses & proximable regularizers).
//!
//! * The classic formulations still apply exactly the closed-form
//!   backward maps of §III.A — asserted against from-scratch reference
//!   operators written inline here (the same arithmetic, outside the
//!   `SharedProx` machinery), with deterministic runs compared bitwise.
//! * The two formulations shipped through the open API — the
//!   graph-Laplacian relationship coupling and mean-regularized
//!   clustering — converge under all three schedules, both in-proc and
//!   through the real CLI (`--reg graph` / `--reg mean`), and their
//!   state survives a checkpoint/`--resume` cycle.

use amtl::coordinator::{MtlProblem, SemiSync, Session, Synchronized};
use amtl::data::synthetic;
use amtl::linalg::Mat;
use amtl::optim::prox::RegularizerKind;
use amtl::optim::svd::{Svd, SvdMode};
use amtl::optim::FormulationSpec;
use amtl::util::Rng;
use std::path::PathBuf;
use std::process::Command;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("amtl_iform_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn classic_problem(seed: u64, kind: RegularizerKind, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&[30; 4], 6, 2, 0.1, &mut rng);
    MtlProblem::new(ds, kind, lambda, 0.5, &mut rng)
}

fn spec_problem(seed: u64, spec: &str, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&[30; 4], 6, 2, 0.1, &mut rng);
    MtlProblem::try_new(ds, FormulationSpec::parse(spec).unwrap(), lambda, 0.5, &mut rng)
        .unwrap()
}

#[inline]
fn soft(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

// --------------------------------------- classic math survives the redesign

#[test]
fn classic_formulations_apply_the_closed_form_backward_map_bitwise() {
    // For each pre-redesign formulation, a deterministic Synchronized run
    // under the trait-based server must produce a final iterate equal —
    // bit for bit — to the closed-form prox of the final auxiliary state,
    // computed here with raw operators (no SharedProx involved). This is
    // the "bitwise-identical before/after" acceptance check: the closed
    // forms below are the exact arithmetic the pre-redesign enum ran.
    for (kind, lambda) in [
        (RegularizerKind::Nuclear, 0.3),
        (RegularizerKind::L21, 0.4),
        (RegularizerKind::ElasticNet, 0.2),
    ] {
        let p = classic_problem(900, kind, lambda);
        let run = || {
            Session::builder(&p)
                .iters_per_node(12)
                .eta_k(0.9)
                .svd(SvdMode::Exact) // exact path: prox is pure closed form
                .record_every(1_000_000)
                .schedule(Synchronized)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let r = run();
        let tau = p.eta * lambda;
        let v = &r.v_final;
        let reference = match kind {
            RegularizerKind::Nuclear => Svd::jacobi(v).shrink_reconstruct(tau),
            RegularizerKind::L21 => {
                // Mirrors the row-shrinkage arithmetic op for op so the
                // comparison can be bitwise.
                let mut w = v.clone();
                for row in 0..w.rows() {
                    let mut nrm = 0.0;
                    for c in 0..w.cols() {
                        let x = w.get(row, c);
                        nrm += x * x;
                    }
                    nrm = nrm.sqrt();
                    let scale = if nrm > tau { (nrm - tau) / nrm } else { 0.0 };
                    for c in 0..w.cols() {
                        w.set(row, c, w.get(row, c) * scale);
                    }
                }
                w
            }
            RegularizerKind::ElasticNet => {
                // γ = 1 (the classic factory's default).
                let mut w = v.clone();
                let scale = 1.0 / (1.0 + tau);
                for x in w.data_mut() {
                    *x = soft(*x, tau) * scale;
                }
                w
            }
            _ => unreachable!(),
        };
        assert_eq!(
            r.w_final, reference,
            "{kind:?}: trait-based backward map must equal the closed form bitwise"
        );

        // Determinism: the exact same run yields bit-identical objectives
        // (so any silent change to the math would trip this test).
        let r2 = run();
        assert_eq!(
            p.objective(&r.w_final).to_bits(),
            p.objective(&r2.w_final).to_bits(),
            "{kind:?}: synchronized runs must be bitwise reproducible"
        );
        assert_eq!(r.updates, 48);
    }
}

#[test]
fn nuclear_online_default_is_deterministic_and_tracks_exact() {
    // The default (incremental) nuclear path after the redesign: same
    // run twice is bitwise identical, and it stays within the documented
    // tolerance of the exact backward map.
    let p = classic_problem(901, RegularizerKind::Nuclear, 0.3);
    let run = |mode: SvdMode| {
        Session::builder(&p)
            .iters_per_node(12)
            .eta_k(0.9)
            .svd(mode)
            .record_every(1_000_000)
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let a = run(SvdMode::Online);
    let b = run(SvdMode::Online);
    assert_eq!(a.w_final, b.w_final, "online runs must be reproducible");
    let exact = run(SvdMode::Exact);
    assert!(
        a.w_final.max_abs_diff(&exact.w_final) < 1e-6,
        "online diverged from exact: {}",
        a.w_final.max_abs_diff(&exact.w_final)
    );
}

// ----------------------------------------------- the two new formulations

#[test]
fn graph_and_mean_converge_under_all_three_schedules() {
    for spec in ["graph:topology=ring,weight=0.5", "mean"] {
        let p = spec_problem(902, spec, 0.3);
        let f0 = p.objective(&p.prox_map(&Mat::zeros(p.d(), p.t())));
        let base = || Session::builder(&p).iters_per_node(60).eta_k(0.9);
        for (name, r) in [
            ("amtl", base().build().unwrap().run().unwrap()),
            ("smtl", base().schedule(Synchronized).build().unwrap().run().unwrap()),
            (
                "semisync",
                base()
                    .schedule(SemiSync { staleness_bound: 2 })
                    .build()
                    .unwrap()
                    .run()
                    .unwrap(),
            ),
        ] {
            assert_eq!(r.updates, 240, "{spec} under {name}");
            let f1 = p.objective(&r.w_final);
            assert!(f1.is_finite(), "{spec} under {name}: objective not finite");
            assert!(
                f1 < 0.3 * f0,
                "{spec} under {name}: objective {f0} -> {f1} did not converge"
            );
        }
    }
}

#[test]
fn mean_incremental_centroid_refreshes_through_the_server_hooks() {
    // The mean formulation's incremental path rides the same
    // stage/coalesce/refresh plumbing as the online nuclear prox: with a
    // small refresh stride the run must report refreshes, and the
    // incremental default must agree with the exact path.
    let p = spec_problem(903, "mean", 0.4);
    let run = |mode: SvdMode| {
        Session::builder(&p)
            .iters_per_node(40)
            .eta_k(0.9)
            .svd(mode)
            .resvd_every(8)
            .record_every(1_000_000)
            .schedule(Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let online = run(SvdMode::Online);
    assert!(
        online.svd_refreshes >= 1,
        "refresh stride 8 over {} updates must trigger refreshes",
        online.updates
    );
    let p_exact = spec_problem(903, "mean", 0.4);
    let exact = Session::builder(&p_exact)
        .iters_per_node(40)
        .eta_k(0.9)
        .svd(SvdMode::Exact)
        .record_every(1_000_000)
        .schedule(Synchronized)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        online.w_final.max_abs_diff(&exact.w_final) < 1e-9,
        "incremental centroid diverged from exact: {}",
        online.w_final.max_abs_diff(&exact.w_final)
    );
}

#[test]
fn graph_and_mean_survive_a_checkpoint_resume_cycle() {
    // Partial run → drop → resume must land exactly where an
    // uninterrupted run lands (Synchronized ⇒ deterministic), proving the
    // formulations' state_save/state_load hooks round-trip through the
    // snapshot + WAL machinery.
    for (name, spec) in [("graph", "graph:topology=ring,weight=0.5"), ("mean", "mean")] {
        let dir = tmp_dir(&format!("resume_{name}"));
        let p = spec_problem(904, spec, 0.3);
        let run = |iters: usize, resume: bool, checkpoint: bool| {
            let mut b = Session::builder(&p)
                .iters_per_node(iters)
                .eta_k(0.9)
                .record_every(1_000_000)
                .schedule(Synchronized);
            if checkpoint {
                b = b.checkpoint_dir(Some(dir.clone())).checkpoint_every(5).resume(resume);
            }
            b.build().unwrap().run().unwrap()
        };
        let partial = run(6, false, true);
        assert_eq!(partial.updates, 24, "{name}: 6 rounds x 4 nodes");
        let resumed = run(15, true, true);
        assert_eq!(resumed.updates, 36, "{name}: 9 resumed rounds x 4 nodes");
        assert!(resumed.wal_replayed > 0 || resumed.checkpoints_written > 0, "{name}");
        let uninterrupted = run(15, false, false);
        assert_eq!(
            resumed.v_final, uninterrupted.v_final,
            "{name}: resumed V must be bitwise identical"
        );
        assert_eq!(
            resumed.w_final, uninterrupted.w_final,
            "{name}: resumed W must be bitwise identical"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn resume_rejects_a_mismatched_formulation_or_lambda() {
    // A checkpoint written under one formulation must not silently resume
    // under another (the server would prox with one coupling while
    // objectives are reported with a different one).
    let dir = tmp_dir("resume_mismatch");
    let p = spec_problem(905, "mean", 0.3);
    let _ = Session::builder(&p)
        .iters_per_node(3)
        .record_every(1_000_000)
        .checkpoint_dir(Some(dir.clone()))
        .checkpoint_every(2)
        .schedule(Synchronized)
        .build()
        .unwrap()
        .run()
        .unwrap();

    let other = spec_problem(905, "graph:topology=ring,weight=0.5", 0.3);
    let err = Session::builder(&other)
        .iters_per_node(6)
        .checkpoint_dir(Some(dir.clone()))
        .resume(true)
        .schedule(Synchronized)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(format!("{err}").contains("formulation"), "{err}");

    let other = spec_problem(905, "mean", 0.7);
    let err = Session::builder(&other)
        .iters_per_node(6)
        .checkpoint_dir(Some(dir.clone()))
        .resume(true)
        .schedule(Synchronized)
        .build()
        .unwrap()
        .run()
        .unwrap_err();
    assert!(format!("{err}").contains("lambda"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------------ CLI coverage

fn amtl_bin() -> &'static str {
    env!("CARGO_BIN_EXE_amtl")
}

/// Run `amtl train` with the given extra args on a tiny problem and
/// return (first trajectory objective, final objective) parsed from
/// stdout.
fn train_objectives(extra: &[&str]) -> (f64, f64) {
    let mut cmd = Command::new(amtl_bin());
    cmd.args([
        "train", "--tasks", "3", "--n", "20", "--dim", "5", "--iters", "25", "--eta-k", "0.9",
        "--seed", "11",
    ]);
    cmd.args(extra);
    let out = cmd.output().expect("spawn amtl");
    assert!(
        out.status.success(),
        "amtl train {extra:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let first = stdout
        .lines()
        .find_map(|l| l.trim_start().strip_prefix("t=").and_then(|l| l.split("F=").nth(1)))
        .and_then(|f| f.trim().parse::<f64>().ok())
        .unwrap_or_else(|| panic!("no trajectory line in:\n{stdout}"));
    let last = stdout
        .lines()
        .find_map(|l| l.strip_prefix("final objective:"))
        .and_then(|l| l.split_whitespace().next())
        .and_then(|f| f.parse::<f64>().ok())
        .unwrap_or_else(|| panic!("no final objective in:\n{stdout}"));
    (first, last)
}

#[test]
fn cli_runs_graph_and_mean_under_every_method() {
    for reg in ["graph", "mean"] {
        for method in ["amtl", "smtl", "semisync"] {
            let mut args = vec!["--reg", reg, "--method", method, "--lambda", "0.3"];
            if method == "semisync" {
                args.extend_from_slice(&["--staleness", "2"]);
            }
            let (first, last) = train_objectives(&args);
            assert!(
                last.is_finite() && last < first,
                "--reg {reg} --method {method}: objective {first} -> {last}"
            );
        }
    }
}

#[test]
fn cli_accepts_a_graph_file() {
    let dir = tmp_dir("graph_file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("graph.json");
    std::fs::write(
        &path,
        r#"{ "tasks": 3, "edges": [[0, 1, 1.0], [1, 2, 1.0]] }"#,
    )
    .unwrap();
    let path_s = path.to_str().unwrap().to_string();
    let (first, last) =
        train_objectives(&["--reg", "graph", "--graph-file", &path_s, "--lambda", "0.3"]);
    assert!(last.is_finite() && last < first, "{first} -> {last}");
    std::fs::remove_dir_all(&dir).ok();
}

fn train_fails_with(extra: &[&str], needle: &str) {
    let mut cmd = Command::new(amtl_bin());
    cmd.args(["train", "--tasks", "2", "--n", "10", "--dim", "4", "--iters", "2"]);
    cmd.args(extra);
    let out = cmd.output().expect("spawn amtl");
    assert!(!out.status.success(), "amtl train {extra:?} unexpectedly succeeded");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains(needle), "stderr for {extra:?} missing '{needle}': {stderr}");
}

#[test]
fn cli_rejects_contradictory_and_malformed_flags() {
    // Unknown formulation: the error lists the registry.
    train_fails_with(&["--reg", "bogus"], "graph");
    // Refresh stride under the exact backend.
    train_fails_with(&["--svd", "exact", "--resvd-every", "8"], "--resvd-every");
    // Staleness bound outside semisync.
    train_fails_with(&["--method", "amtl", "--staleness", "3"], "--staleness");
    // Graph file with a non-graph formulation.
    train_fails_with(
        &["--reg", "nuclear", "--graph-file", "/nonexistent.json"],
        "--graph-file",
    );
    // Unknown formulation parameter.
    train_fails_with(&["--reg", "mean:weight=2"], "does not take parameter");
}
