//! Opt-in chaos soak: multi-seed, multi-schedule storm sweeps with the
//! full invariant battery. Gated behind `AMTL_SOAK=1` because a sweep
//! takes minutes, not seconds:
//!
//! ```text
//! AMTL_SOAK=1 cargo test --release --test soak_chaos -- --nocapture
//! ```
//!
//! Without the gate every test returns immediately (and says so), which
//! is what the CI smoke lane runs to keep the harness compiling. Any
//! failure prints the storm's repro line — feed its seed back through
//! `cargo run --release --example chaos_run -- --seed <n>` or a one-off
//! plan to reproduce it exactly.

use amtl::chaos::{run_resumed_storm, run_storm, ChaosPlan, ScheduleChoice, StormReport};
use amtl::coordinator::MtlProblem;
use amtl::data::synthetic;
use amtl::optim::prox::RegularizerKind;
use amtl::transport::TransportKind;
use amtl::util::Rng;
use std::path::PathBuf;

fn soaking() -> bool {
    let on = std::env::var("AMTL_SOAK").map(|v| v == "1").unwrap_or(false);
    if !on {
        println!("AMTL_SOAK != 1 — soak skipped");
    }
    on
}

fn problem(seed: u64, nodes: usize) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![40; nodes], 8, 3, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, 0.3, 0.5, &mut rng)
}

fn artifact_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("amtl-chaos-soak").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_passed(report: &StormReport) {
    assert!(
        report.passed(),
        "soak storm violated invariants:\n{}\n{:#?}",
        report.repro_line(),
        report.violations
    );
    println!("   {}", report.summary());
}

#[test]
fn soak_inproc_storms_across_all_schedules_and_seeds() {
    if !soaking() {
        return;
    }
    let schedules = [
        ScheduleChoice::Async,
        ScheduleChoice::Synchronized,
        ScheduleChoice::SemiSync { staleness_bound: 6 },
    ];
    for seed in [11, 222, 3333] {
        for schedule in schedules {
            let mut plan = ChaosPlan::new(64, 48, seed);
            plan.schedule = schedule;
            let p = problem(plan.seed, plan.nodes);
            let report =
                run_storm(&p, &plan, &artifact_dir(&format!("inproc-{}-{seed}", schedule.name())))
                    .unwrap();
            assert_passed(&report);
        }
    }
}

#[test]
fn soak_tcp_storms_cross_the_real_wire() {
    if !soaking() {
        return;
    }
    for seed in [17, 1717] {
        for schedule in [ScheduleChoice::Async, ScheduleChoice::SemiSync { staleness_bound: 8 }] {
            let mut plan = ChaosPlan::new(16, 32, seed);
            plan.schedule = schedule;
            plan.transport = TransportKind::Tcp;
            let p = problem(plan.seed, plan.nodes);
            let report =
                run_storm(&p, &plan, &artifact_dir(&format!("tcp-{}-{seed}", schedule.name())))
                    .unwrap();
            assert_passed(&report);
        }
    }
}

#[test]
fn soak_resumed_storms_keep_invariants_across_restarts() {
    if !soaking() {
        return;
    }
    for seed in [29, 2929] {
        let plan = ChaosPlan::new(32, 40, seed);
        let p = problem(plan.seed, plan.nodes);
        let report =
            run_resumed_storm(&p, &plan, &artifact_dir(&format!("resumed-{seed}"))).unwrap();
        assert_eq!(report.legs.len(), 2);
        assert_passed(&report);
    }
}

#[test]
fn soak_hot_storm_still_converges() {
    if !soaking() {
        return;
    }
    // Crank every dial: a third of the swarm flaps, a quarter drops, a
    // quarter straggles. Convergence tolerance stays the default — the
    // KM averaging has to absorb all of it.
    let mut plan = ChaosPlan::new(96, 64, 424242);
    plan.storm.drop_p = 0.25;
    plan.storm.flap_fraction = 1.0 / 3.0;
    plan.storm.straggler_fraction = 0.25;
    let p = problem(plan.seed, plan.nodes);
    let report = run_storm(&p, &plan, &artifact_dir("hot")).unwrap();
    assert_passed(&report);
}
