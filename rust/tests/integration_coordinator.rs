//! Integration tests over the coordinator: AMTL/SMTL end-to-end behaviour,
//! straggler robustness, heterogeneous losses, failure modes, and the
//! dynamic step size. All on the native engine (fast, deterministic) —
//! PJRT equivalence is covered by `integration_runtime.rs`.

use amtl::coordinator::step_size::KmSchedule;
use amtl::coordinator::{Async, MtlProblem, RunConfig, RunResult, Schedule, Session, Synchronized};
use amtl::data::{public, synthetic};
use amtl::experiments::{run_amtl_once, run_smtl_once, ExpConfig};
use amtl::net::DelayModel;
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::Engine;
use amtl::util::Rng;
use std::time::Duration;

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

fn run_schedule(
    p: &MtlProblem,
    cfg: &RunConfig,
    schedule: impl Schedule + 'static,
) -> anyhow::Result<RunResult> {
    Session::builder(p)
        .engine(Engine::Native)
        .config(cfg.clone())
        .schedule(schedule)
        .build()?
        .run()
}

// ---------------------------------------------------------------- timing

#[test]
fn amtl_beats_smtl_under_delays() {
    // The paper's headline claim, at miniature scale: same network, same
    // iteration budget, AMTL finishes first.
    let p = lowrank_problem(200, 6, 30, 8, 0.3);
    let cfg = ExpConfig {
        iters: 5,
        offset_units: 2.0,
        time_scale: Duration::from_millis(5),
        ..Default::default()
    };
    let a = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
    let s = run_smtl_once(&p, Engine::Native, None, &cfg).unwrap();
    assert!(
        a.wall_time < s.wall_time,
        "AMTL {:?} should beat SMTL {:?}",
        a.wall_time,
        s.wall_time
    );
}

#[test]
fn one_straggler_does_not_stall_amtl() {
    // One node is 30x slower than the rest; in AMTL the fast nodes finish
    // their budget without waiting on it.
    let p = lowrank_problem(202, 5, 20, 6, 0.3);
    let fast = DelayModel::OffsetJitter {
        offset: Duration::from_millis(1),
        jitter: Duration::ZERO,
    };
    let slow = DelayModel::OffsetJitter {
        offset: Duration::from_millis(30),
        jitter: Duration::ZERO,
    };
    let cfg = RunConfig {
        iters_per_node: 5,
        delay: DelayModel::PerNode {
            per_node: vec![
                Box::new(slow),
                Box::new(fast.clone()),
                Box::new(fast.clone()),
                Box::new(fast.clone()),
                Box::new(fast),
            ],
        },
        ..Default::default()
    };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    // Straggler: 5 × 30ms = 150ms; wall ≈ straggler's own budget, not T× it.
    assert!(r.wall_time < Duration::from_millis(400), "wall {:?}", r.wall_time);
    assert_eq!(r.updates, 25);
}

// ------------------------------------------------------------ correctness

#[test]
fn amtl_and_smtl_agree_with_centralized_fista() {
    let p = lowrank_problem(203, 5, 60, 8, 0.5);
    let tasks = p.fista_tasks();
    let mut reg = p.regularizer();
    let f_star = *amtl::optim::fista::fista(&tasks, &mut reg, p.l_max, 3000, 1e-12)
        .history
        .last()
        .unwrap();

    let cfg = ExpConfig { iters: 500, eta_k: 0.9, ..Default::default() };
    let fa = p.objective(&run_amtl_once(&p, Engine::Native, None, &cfg).unwrap().w_final);
    let fs = p.objective(&run_smtl_once(&p, Engine::Native, None, &cfg).unwrap().w_final);
    assert!(fa <= f_star * 1.03 + 1e-6, "AMTL {fa} vs F* {f_star}");
    assert!(fs <= f_star * 1.03 + 1e-6, "SMTL {fs} vs F* {f_star}");
}

#[test]
fn nuclear_coupling_beats_single_task_learning_on_lowrank_family() {
    // Knowledge transfer: with few samples per task and shared structure,
    // the coupled solution recovers the planted models better than
    // decoupled per-task fits.
    let mut rng = Rng::new(204);
    // 15 samples per task in d=20 — underdetermined per task.
    let train = synthetic::lowrank_regression(&[15; 8], 20, 2, 0.2, &mut rng);
    let w_true = train.w_true.clone().unwrap();

    let mtl = MtlProblem::new(train.clone(), RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    let mut stl = MtlProblem::new(train, RegularizerKind::None, 0.0, 0.5, &mut rng);
    stl.eta = mtl.eta;

    let cfg = ExpConfig { iters: 300, eta_k: 0.9, ..Default::default() };
    let w_mtl = run_amtl_once(&mtl, Engine::Native, None, &cfg).unwrap().w_final;
    let w_stl = run_amtl_once(&stl, Engine::Native, None, &cfg).unwrap().w_final;

    let err = |w: &amtl::linalg::Mat| w.add_scaled(-1.0, &w_true).frobenius_norm();
    let e_mtl = err(&w_mtl);
    let e_stl = err(&w_stl);
    assert!(
        e_mtl < e_stl,
        "MTL recovery {e_mtl} should beat STL {e_stl} in the scarce-data regime"
    );
}

#[test]
fn l21_l1_and_elasticnet_formulations_also_converge() {
    // The framework covers the MALSAR-style formulations, not just nuclear.
    for kind in [RegularizerKind::L21, RegularizerKind::ElasticNet, RegularizerKind::L1] {
        let mut rng = Rng::new(205);
        let ds = synthetic::lowrank_regression(&[40; 4], 10, 2, 0.1, &mut rng);
        let p = MtlProblem::new(ds, kind, 0.3, 0.5, &mut rng);
        let cfg = ExpConfig { iters: 200, eta_k: 0.9, ..Default::default() };
        let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
        let f0 = p.objective(&amtl::linalg::Mat::zeros(10, 4));
        let f1 = p.objective(&r.w_final);
        assert!(f1 < 0.3 * f0, "{kind:?}: {f0} -> {f1}");
    }
}

#[test]
fn logistic_tasks_converge_too() {
    let mut rng = Rng::new(206);
    let ds = synthetic::lowrank_classification(&[80; 4], 10, 2, &mut rng);
    let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.1, 0.5, &mut rng);
    let cfg = ExpConfig { iters: 300, eta_k: 0.9, ..Default::default() };
    let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
    let f0 = p.objective(&amtl::linalg::Mat::zeros(10, 4));
    let f1 = p.objective(&r.w_final);
    assert!(f1 < 0.8 * f0, "logistic: {f0} -> {f1}");
}

#[test]
fn heterogeneous_losses_in_one_problem() {
    // §III.A: "some tasks can be regression while the other tasks are
    // classification."
    let mut rng = Rng::new(207);
    let mut ds = synthetic::lowrank_regression(&[40; 2], 8, 2, 0.1, &mut rng);
    let cls = synthetic::lowrank_classification(&[40; 2], 8, 2, &mut rng);
    ds.tasks.extend(cls.tasks);
    ds.w_true = None;
    let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.2, 0.5, &mut rng);
    let cfg = ExpConfig { iters: 150, eta_k: 0.9, ..Default::default() };
    let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
    let f0 = p.objective(&amtl::linalg::Mat::zeros(8, 4));
    assert!(p.objective(&r.w_final) < f0);
}

// ------------------------------------------------------------ dynamic step

#[test]
fn dynamic_step_reaches_lower_objective_under_delay() {
    // Tables IV–VI shape at miniature scale.
    let run = |dynamic: bool| {
        let p = lowrank_problem(208, 5, 50, 10, 0.5);
        let cfg = ExpConfig {
            iters: 10,
            offset_units: 10.0,
            time_scale: Duration::from_millis(2),
            eta_k: 0.3,
            dynamic_step: dynamic,
            ..Default::default()
        };
        let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
        p.objective(&r.w_final)
    };
    let fixed = run(false);
    let dynamic = run(true);
    assert!(
        dynamic < fixed,
        "dynamic step {dynamic} should beat fixed {fixed} within 10 iterations"
    );
}

// ---------------------------------------------------------- public datasets

#[test]
fn school_sim_full_run_is_stable() {
    let mut rng = Rng::new(209);
    let ds = public::by_name("school-small", &mut rng).unwrap();
    let p = MtlProblem::new(ds, RegularizerKind::Nuclear, 1.0, 0.5, &mut rng);
    let cfg = ExpConfig { iters: 20, eta_k: 0.5, ..Default::default() };
    let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
    assert!(p.objective(&r.w_final).is_finite());
    assert_eq!(r.updates, 20 * p.t() as u64);
}

// --------------------------------------------------------------- smtl misc

#[test]
fn smtl_trajectory_is_monotone_decreasing_for_safe_steps() {
    let p = lowrank_problem(210, 4, 50, 8, 0.3);
    let cfg = RunConfig {
        iters_per_node: 40,
        km: KmSchedule::fixed(0.9),
        record_every: 4,
        ..Default::default()
    };
    let r = run_schedule(&p, &cfg, Synchronized).unwrap();
    let objs = r.compute_objectives(|w| p.objective(w), |v| p.prox_map(v));
    let mut violations = 0;
    for w in objs.windows(2) {
        if w[1].2 > w[0].2 * 1.001 {
            violations += 1;
        }
    }
    assert!(violations <= 1, "{violations} non-monotone steps");
}

#[test]
fn zero_iteration_runs_are_clean() {
    let p = lowrank_problem(211, 3, 10, 4, 0.1);
    let cfg = RunConfig { iters_per_node: 0, ..Default::default() };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    assert_eq!(r.updates, 0);
    assert_eq!(r.v_final, amtl::linalg::Mat::zeros(4, 3));
    let cfg = RunConfig { iters_per_node: 0, ..Default::default() };
    let r = run_schedule(&p, &cfg, Synchronized).unwrap();
    assert_eq!(r.updates, 0);
}

#[test]
fn mismatched_compute_count_is_an_error() {
    let p = lowrank_problem(212, 3, 10, 4, 0.1);
    let mut computes = p.build_computes(Engine::Native, None).unwrap();
    computes.pop();
    assert!(Session::builder(&p).computes(computes).build().is_err());
}

#[test]
fn prox_every_tradeoff_preserves_convergence() {
    // Batched backward steps (prox_every > 1) still converge to a similar
    // objective — the knob trades staleness for server throughput (§III.C).
    let p = lowrank_problem(213, 4, 40, 8, 0.3);
    let f = |prox_every: u64| {
        let cfg = ExpConfig { iters: 200, eta_k: 0.9, prox_every, ..Default::default() };
        let r = run_amtl_once(&p, Engine::Native, None, &cfg).unwrap();
        p.objective(&r.w_final)
    };
    let f1 = f(1);
    let f4 = f(4);
    assert!((f4 - f1).abs() / f1 < 0.05, "prox_every=4 {f4} vs =1 {f1}");
}

#[test]
fn online_svd_default_converges_on_small_problem() {
    // The incremental prox is the default; pin it explicitly with a short
    // refresh stride and check convergence plus the refresh accounting.
    let p = lowrank_problem(214, 3, 30, 6, 0.2);
    let cfg = RunConfig {
        iters_per_node: 100,
        km: KmSchedule::fixed(0.9),
        svd: amtl::optim::svd::SvdMode::Online,
        resvd_every: 16,
        ..Default::default()
    };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    let f0 = p.objective(&amtl::linalg::Mat::zeros(6, 3));
    let f1 = p.objective(&r.w_final);
    assert!(f1 < 0.2 * f0, "online-SVD run: {f0} -> {f1}");
    assert!(r.svd_refreshes >= 1, "300 commits at stride 16 must refresh");
}

// ------------------------------------------------------------ faults

#[test]
fn dropped_updates_are_counted_and_progress_continues() {
    use amtl::net::FaultModel;
    let p = lowrank_problem(215, 4, 40, 6, 0.3);
    let cfg = RunConfig {
        iters_per_node: 100,
        km: KmSchedule::fixed(0.9),
        faults: FaultModel::DropActivation { p: 0.3 },
        ..Default::default()
    };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    assert!(r.dropped_updates > 50, "expected ~120 drops, got {}", r.dropped_updates);
    assert_eq!(r.updates + r.dropped_updates, 400);
    // Despite 30% loss, the run still converges substantially.
    let f0 = p.objective(&amtl::linalg::Mat::zeros(6, 4));
    assert!(p.objective(&r.w_final) < 0.2 * f0);
}

#[test]
fn crashed_node_freezes_its_block_but_others_finish() {
    use amtl::net::FaultModel;
    let p = lowrank_problem(216, 4, 30, 6, 0.3);
    let cfg = RunConfig {
        iters_per_node: 50,
        km: KmSchedule::fixed(0.9),
        faults: FaultModel::CrashAfter { node: 2, after: 5 },
        ..Default::default()
    };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    assert_eq!(r.crashed_nodes, vec![2]);
    assert_eq!(r.updates_per_node[2], 5);
    for t in [0usize, 1, 3] {
        assert_eq!(r.updates_per_node[t], 50, "node {t} should finish its budget");
    }
    // The surviving blocks still optimize their tasks.
    assert!(p.objective(&r.w_final).is_finite());
}

#[test]
fn crash_restart_storms_hold_invariants_across_all_schedules() {
    // Satellite of the chaos harness: a 16-node swarm where HALF the
    // nodes flap (silent crash/restart windows long enough to guarantee
    // eviction), under every schedule. The harness machine-checks
    // exactly-once, convergence, membership balance, and (semisync) the
    // staleness bound; on top we assert the storm actually bit — flapped
    // nodes were evicted AND re-registered — and close the exactly-once
    // accounting by hand: every non-offline activation is either an
    // applied update or a counted drop, nothing double-applied, nothing
    // lost.
    use amtl::chaos::{run_storm, ChaosPlan, ScheduleChoice};
    use amtl::util::json::Json;

    let schedules = [
        ScheduleChoice::Async,
        ScheduleChoice::Synchronized,
        ScheduleChoice::SemiSync { staleness_bound: 6 },
    ];
    for schedule in schedules {
        let mut plan = ChaosPlan::new(16, 32, 777);
        plan.schedule = schedule;
        plan.storm.flap_fraction = 0.5;
        let p = lowrank_problem(777, 16, 30, 6, 0.2);
        let dir = std::env::temp_dir()
            .join("amtl-chaos-coordinator")
            .join(schedule.name());
        std::fs::create_dir_all(&dir).unwrap();
        let report = run_storm(&p, &plan, &dir).unwrap();
        assert!(
            report.passed(),
            "{}: {:?}\n{}",
            schedule.name(),
            report.violations,
            report.repro_line()
        );
        assert_eq!(report.flapped.len(), 8, "half the swarm flaps");

        // Exactly-once accounting: 16 × 32 activations minus the 8 × 8
        // silently-lost window slots, each ending as apply or drop.
        let r = &report.legs[0];
        let applied: u64 = r.updates_per_node.iter().sum();
        assert_eq!(applied, r.updates);
        assert_eq!(r.updates + r.dropped_updates, 16 * 32 - 8 * 8, "{}", schedule.name());
        assert!(r.dropped_updates > 0, "the drop storm must actually drop");
        assert!(r.evicted_nodes.is_empty(), "every flapped node rejoined");

        // The membership storm really happened: count trace events.
        let text = std::fs::read_to_string(&report.trace_paths[0]).unwrap();
        let mut evictions = vec![0u64; 16];
        let mut registers = vec![0u64; 16];
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let v = Json::parse(line).unwrap();
            let event = v.get("event").and_then(Json::as_str).unwrap_or_default();
            if let Some(node) = v.get("node").and_then(Json::as_usize) {
                match event {
                    "eviction" => evictions[node] += 1,
                    "register" => registers[node] += 1,
                    _ => {}
                }
            }
        }
        if schedule.registers_membership() {
            for &t in &report.flapped {
                assert!(evictions[t] >= 1, "{}: flapped node {t} must be evicted", schedule.name());
                assert!(
                    registers[t] >= 2,
                    "{}: flapped node {t} must re-register",
                    schedule.name()
                );
            }
            for t in (0..16).filter(|t| !report.flapped.contains(t)) {
                assert_eq!(evictions[t], 0, "{}: cohort node {t} stayed live", schedule.name());
            }
        } else {
            // The barrier loop never registers: the storm is pure math.
            assert_eq!(evictions.iter().sum::<u64>() + registers.iter().sum::<u64>(), 0);
        }
    }
}

#[test]
fn perf_counters_are_populated() {
    let p = lowrank_problem(217, 3, 50, 8, 0.3);
    let cfg = RunConfig { iters_per_node: 20, ..Default::default() };
    let r = run_schedule(&p, &cfg, Async).unwrap();
    assert!(r.compute_secs > 0.0, "forward-compute time must be measured");
    assert!(r.backward_wait_secs > 0.0, "backward-wait time must be measured");
    // Sanity: both are bounded by total wall × nodes.
    let bound = r.wall_time.as_secs_f64() * 3.0;
    assert!(r.compute_secs <= bound && r.backward_wait_secs <= bound);
}

// ------------------------------------------------------------- SGD variant

#[test]
fn sgd_forward_steps_converge() {
    // The paper's future-work extension: stochastic forward steps. With an
    // importance-corrected half-batch, AMTL still converges close to the
    // full-batch objective.
    let p = lowrank_problem(218, 4, 80, 8, 0.3);
    let full_cfg = RunConfig {
        iters_per_node: 150,
        km: KmSchedule::fixed(0.9),
        ..Default::default()
    };
    let sgd_cfg = RunConfig {
        iters_per_node: 150,
        km: KmSchedule::fixed(0.9),
        sgd_fraction: Some(0.5),
        ..Default::default()
    };
    let r_full = run_schedule(&p, &full_cfg, Async).unwrap();
    let r_sgd = run_schedule(&p, &sgd_cfg, Async).unwrap();
    let f_full = p.objective(&r_full.w_final);
    let f_sgd = p.objective(&r_sgd.w_final);
    let f0 = p.objective(&amtl::linalg::Mat::zeros(8, 4));
    assert!(f_sgd < 0.1 * f0, "SGD run must still optimize: {f0} -> {f_sgd}");
    assert!(
        f_sgd < 3.0 * f_full.max(1e-3) + 1.0,
        "SGD {f_sgd} should land near full-batch {f_full}"
    );
}

#[test]
fn sgd_minibatch_gradient_is_unbiased() {
    // Averaging many minibatch steps approximates the full-batch step.
    use amtl::runtime::{make_task_computes, TaskCompute};
    let mut rng = Rng::new(219);
    let ds = synthetic::lowrank_regression(&[200], 6, 2, 0.1, &mut rng);
    let mut computes = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
    let w = rng.normal_vec(6);
    let eta = 1e-3;
    let (u_full, _) = computes[0].step(&w, eta).unwrap();
    let trials = 400;
    let mut mean_u = vec![0.0; 6];
    for _ in 0..trials {
        let (u, _) = computes[0].step_minibatch(&w, eta, 0.25, &mut rng).unwrap();
        for (m, ui) in mean_u.iter_mut().zip(&u) {
            *m += ui / trials as f64;
        }
    }
    for (m, f) in mean_u.iter().zip(&u_full) {
        let scale = f.abs().max(0.1);
        assert!((m - f).abs() / scale < 0.15, "mean {m} vs full {f}");
    }
}
