//! Integration tests for the observability layer: a traced 2-node TCP
//! training run leaves a well-formed JSONL timeline (every commit
//! present exactly once, strictly ordered per node), and the
//! `FetchMetrics` wire frame is answered by both the trainer and a read
//! replica.

use amtl::coordinator::{MtlProblem, RunConfig, Session};
use amtl::data::synthetic;
use amtl::obs::fleet::{self, Hop};
use amtl::obs::TraceWriter;
use amtl::optim::prox::RegularizerKind;
use amtl::serve::{ModelReplica, PredictClient, ReplicaServer};
use amtl::transport::{TcpClient, TcpOptions, TcpServer, Transport, TransportKind};
use amtl::util::json::Json;
use amtl::util::Rng;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(5);

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("amtl_iobs_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

// ------------------------------------------------ trace completeness

#[test]
fn tcp_run_trace_is_ordered_and_complete() {
    // A traced 2-node run over real loopback sockets must leave a JSONL
    // file from which the per-node commit timeline reconstructs exactly:
    // every line parses, every commit appears once with its staleness,
    // and each node's activation counters are strictly increasing.
    let dir = tmp_dir("trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.jsonl");
    let iters = 30usize;
    let p = lowrank_problem(6500, 2, 40, 6, 0.25);
    let trace = Arc::new(TraceWriter::create(&path).unwrap());
    let r = Session::builder(&p)
        .iters_per_node(iters)
        .eta_k(0.9)
        .record_every(1_000_000)
        .transport(TransportKind::Tcp)
        .trace(Some(Arc::clone(&trace)))
        .build()
        .unwrap()
        .run()
        .unwrap();
    trace.flush();

    let text = std::fs::read_to_string(&path).unwrap();
    let mut commits_per_node: HashMap<usize, Vec<u64>> = HashMap::new();
    // (node, k) → [(causal rank, start_us)] over every span hop event.
    let mut spans: HashMap<(usize, u64), Vec<(usize, f64)>> = HashMap::new();
    let mut commit_count = 0u64;
    let mut activations = 0u64;
    let mut registers = 0u64;
    for line in text.lines() {
        let j = Json::parse(line).expect("every trace line is one JSON object");
        assert!(j.get("ts_us").and_then(|t| t.as_f64()).is_some(), "ts_us on every event");
        let event = j.get("event").and_then(|e| e.as_str()).expect("event on every line").to_string();
        match event.as_str() {
            "commit" => {
                let node = j.get("node").and_then(|n| n.as_usize()).expect("commit node");
                let k = j.get("k").and_then(|k| k.as_usize()).expect("commit k") as u64;
                assert!(j.get("version").and_then(|v| v.as_usize()).is_some(), "commit version");
                assert!(j.get("staleness").and_then(|s| s.as_f64()).is_some(), "commit staleness");
                commits_per_node.entry(node).or_default().push(k);
                commit_count += 1;
            }
            "activation" => {
                for field in ["node", "k"] {
                    assert!(j.get(field).and_then(|v| v.as_usize()).is_some(), "{field}");
                }
                for field in ["delay_us", "fetch_us", "step_us"] {
                    assert!(j.get(field).and_then(|v| v.as_f64()).is_some(), "{field}");
                }
                activations += 1;
            }
            "register" => {
                assert!(j.get("node").and_then(|n| n.as_usize()).is_some(), "register node");
                for field in ["generation", "col_version"] {
                    assert!(j.get(field).and_then(|v| v.as_f64()).is_some(), "{field}");
                }
                registers += 1;
            }
            "span" => {
                let node = j.get("node").and_then(|n| n.as_usize()).expect("span node");
                let k = j.get("k").and_then(|v| v.as_usize()).expect("span k") as u64;
                let hop_name =
                    j.get("hop").and_then(|h| h.as_str()).expect("span hop").to_string();
                let hop = Hop::from_name(&hop_name)
                    .unwrap_or_else(|| panic!("unknown span hop '{hop_name}'"));
                // The span id is a 16-hex string (ids exceed 2^53, the
                // limit of a JSON double) derived from (node, k).
                let id = j.get("span").and_then(|s| s.as_str()).expect("span id").to_string();
                assert_eq!(
                    id,
                    format!("{:016x}", fleet::span_id(node, k)),
                    "span id derives from (node, k)"
                );
                let start = j.get("start_us").and_then(|v| v.as_f64()).expect("start_us");
                let end = j.get("end_us").and_then(|v| v.as_f64()).expect("end_us");
                assert!(end >= start, "hop {hop_name} ends at or after its start");
                spans.entry((node, k)).or_default().push((hop.causal_rank(), start));
            }
            "prox" | "checkpoint" | "eviction" => {}
            other => panic!("unexpected trace event '{other}'"),
        }
    }
    assert_eq!(commit_count, r.updates, "every commit traced exactly once");
    assert_eq!(activations, r.updates, "no faults injected: every activation commits");
    assert_eq!(registers, p.t() as u64, "each worker registers once at start");
    assert_eq!(commits_per_node.len(), p.t(), "both nodes appear in the timeline");
    for (node, ks) in &commits_per_node {
        assert_eq!(ks.len(), iters, "node {node} commits its whole budget");
        assert!(
            ks.windows(2).all(|w| w[0] < w[1]),
            "node {node} commit events are strictly ordered by k"
        );
        assert_eq!(ks[0], 0, "node {node} starts at activation 0");
        assert_eq!(*ks.last().unwrap(), iters as u64 - 1);
    }
    // Every commit left a complete cross-process span: the worker side
    // emitted node_fetch/node_step/wire_commit, the server side staging
    // (no WAL hop — this run is not durable; prox folds coalesce, so a
    // prox_fold hop joins only the latest staged commit per drain). Hop
    // start timestamps are wall-clock and must be monotone in causal
    // rank — worker and server share this host's clock.
    for (node, ks) in &commits_per_node {
        for &k in ks {
            let mut hops = spans
                .remove(&(*node, k))
                .unwrap_or_else(|| panic!("commit ({node}, {k}) left no span events"));
            hops.sort_by_key(|(rank, _)| *rank);
            let ranks: Vec<usize> = hops.iter().map(|(rank, _)| *rank).collect();
            for need in [Hop::NodeFetch, Hop::NodeStep, Hop::WireCommit, Hop::Staging] {
                assert!(
                    ranks.contains(&need.causal_rank()),
                    "commit ({node}, {k}) span is missing the {} hop: {ranks:?}",
                    need.name()
                );
            }
            assert!(
                hops.windows(2).all(|w| w[0].1 <= w[1].1),
                "commit ({node}, {k}) hop starts not monotone in causal rank: {hops:?}"
            );
        }
    }
    assert!(spans.is_empty(), "span events for uncommitted activations: {spans:?}");
    // The run result carries the staleness summary the trace corroborates.
    assert!(r.mean_staleness.is_finite() && r.mean_staleness >= 0.0);
    assert!(r.staleness_p99 >= r.staleness_p50);
    assert!(r.commit_wait_secs >= 0.0);
    assert!(r.summary().contains("staleness("), "{}", r.summary());
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------ FetchMetrics on both roles

#[test]
fn fetch_metrics_is_answered_by_trainer_and_replica() {
    let dir = tmp_dir("metrics_wire");
    let p = lowrank_problem(6501, 2, 40, 6, 0.25);
    let cfg = RunConfig {
        iters_per_node: 5,
        record_every: 1_000_000,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 4,
        ..Default::default()
    };
    let (_state, server, recorder) = cfg.build_server(&p).unwrap();
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), Some(recorder)).unwrap();
    let addr = handle.addr();

    // Drive real commits through the wire so the trainer has counted
    // traffic to report.
    let mut client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
    let mut rng = Rng::new(11);
    for k in 0..5u64 {
        let _w = client.fetch_prox_col(0).unwrap();
        let u = rng.normal_vec(p.d());
        client.push_update(0, k, 0.5, &u).unwrap();
    }

    // The trainer's TCP server answers FetchMetrics on the same framed
    // socket the predict client speaks.
    let mut mc = PredictClient::connect(addr, TIMEOUT).unwrap();
    let m = mc.metrics().unwrap();
    assert_eq!(m.role_name(), "trainer");
    assert!(m.counter("server.commits").unwrap_or(0) >= 5, "{:?}", m.counters);
    assert!(m.gauge("server.version").unwrap_or(0) >= 5, "{:?}", m.gauges);
    let stale = m.hist("server.staleness").expect("staleness histogram registered");
    assert!(stale.count() >= 5, "one staleness sample per commit");
    assert!(m.counter("wal.appends").unwrap_or(0) >= 5, "durable run logs every commit");
    mc.close().unwrap();

    // The replica answers the same frame, tagged with its role and its
    // serving stats merged in.
    let mut replica = ModelReplica::follow(&dir, Duration::from_millis(5));
    let mut rep = ReplicaServer::spawn("127.0.0.1:0", &replica).unwrap();
    assert!(replica.wait_ready(Duration::from_secs(30)), "genesis snapshot exists");
    let mut pc = PredictClient::connect(rep.addr(), TIMEOUT).unwrap();
    let x = rng.normal_vec(p.d());
    pc.predict(0, &x).unwrap();
    let m = pc.metrics().unwrap();
    assert_eq!(m.role_name(), "replica");
    assert!(m.counter("replica.predictions").unwrap_or(0) >= 1, "{:?}", m.counters);
    assert!(m.gauge("replica.model_seq").is_some());
    assert!(m.hist("replica.predict_us").map(|h| h.count()).unwrap_or(0) >= 1);
    pc.close().unwrap();
    rep.shutdown();
    replica.shutdown();
    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
