//! Integration tests over the PJRT runtime: artifact loading, execution,
//! and PJRT ≡ native cross-checks.
//!
//! These require `artifacts/` to exist (run `make artifacts`); they are
//! skipped gracefully otherwise so `cargo test` stays green on a fresh
//! checkout.

use amtl::data::synthetic;
use amtl::runtime::{
    make_task_computes, ComputePool, Engine, Manifest, PoolConfig, TaskCompute,
};
use amtl::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("AMTL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn pool(executors: usize) -> Option<ComputePool> {
    let dir = artifacts_dir()?;
    Some(ComputePool::new(PoolConfig { executors, artifacts_dir: dir }).expect("pool"))
}

#[test]
fn manifest_loads_and_has_experiment_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.len() >= 10, "expected a full artifact set, got {}", m.len());
    assert_eq!(m.tile_n, 128);
    // Fig 3 buckets.
    assert!(m.bucket_for("lsq_step", 100, 50).is_ok());
    assert!(m.bucket_for("lsq_step", 10000, 50).is_ok());
    assert!(m.bucket_for("lsq_step", 100, 400).is_ok());
    // Public dataset buckets.
    assert!(m.bucket_for("lsq_step", 251, 28).is_ok());
    assert!(m.bucket_for("logistic_step", 14702, 100).is_ok());
    assert!(m.bucket_for("logistic_step", 10000, 10).is_ok());
}

#[test]
fn pjrt_step_matches_native_step_lsq() {
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(500);
    let ds = synthetic::lowrank_regression(&[100], 50, 3, 0.1, &mut rng);
    let mut native = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();

    for trial in 0..5 {
        let w = rng.normal_vec(50);
        let eta = 1e-3 * (trial as f64 + 1.0);
        let (u_n, o_n) = native[0].step(&w, eta).unwrap();
        let (u_p, o_p) = pjrt[0].step(&w, eta).unwrap();
        assert_eq!(u_p.len(), 50);
        let max_diff = u_n
            .iter()
            .zip(&u_p)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        // f32 artifact vs f64 native: tolerance scales with magnitudes.
        let scale = u_n.iter().map(|x| x.abs()).fold(1.0f64, f64::max);
        assert!(max_diff < 1e-3 * scale, "trial {trial}: diff {max_diff} scale {scale}");
        assert!((o_n - o_p).abs() / o_n.max(1.0) < 1e-3, "obj {o_n} vs {o_p}");
    }
}

#[test]
fn pjrt_step_matches_native_step_logistic() {
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(501);
    let ds = synthetic::lowrank_classification(&[100], 50, 3, &mut rng);
    let mut native = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();

    let w = rng.normal_vec(50);
    let (u_n, o_n) = native[0].step(&w, 0.01).unwrap();
    let (u_p, o_p) = pjrt[0].step(&w, 0.01).unwrap();
    let max_diff = u_n
        .iter()
        .zip(&u_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-3, "diff {max_diff}");
    assert!((o_n - o_p).abs() / o_n.max(1.0) < 1e-3);
}

#[test]
fn pjrt_pads_odd_sizes_exactly() {
    // n=77 pads to the 128 bucket; the mask must make padding invisible.
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(502);
    let ds = synthetic::lowrank_regression(&[77], 50, 2, 0.1, &mut rng);
    let mut native = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();
    let w = rng.normal_vec(50);
    let (u_n, o_n) = native[0].step(&w, 1e-3).unwrap();
    let (u_p, o_p) = pjrt[0].step(&w, 1e-3).unwrap();
    let max_diff = u_n
        .iter()
        .zip(&u_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-3, "diff {max_diff}");
    assert!((o_n - o_p).abs() / o_n.max(1.0) < 1e-3);
}

#[test]
fn pool_serves_concurrent_clients() {
    let Some(pool) = pool(2) else { return };
    let mut rng = Rng::new(503);
    let ds = synthetic::lowrank_regression(&[100; 6], 50, 2, 0.1, &mut rng);
    let computes = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();
    let results: Vec<(Vec<f64>, f64)> = std::thread::scope(|s| {
        let handles: Vec<_> = computes
            .into_iter()
            .enumerate()
            .map(|(t, mut c)| {
                s.spawn(move || {
                    let w = vec![0.1 * (t as f64 + 1.0); 50];
                    let mut last = (vec![], 0.0);
                    for _ in 0..10 {
                        last = c.step(&w, 1e-3).unwrap();
                    }
                    last
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(results.len(), 6);
    for (u, obj) in &results {
        assert_eq!(u.len(), 50);
        assert!(obj.is_finite() && *obj >= 0.0);
    }
}

#[test]
fn pjrt_amtl_run_matches_native_amtl_run() {
    use amtl::coordinator::step_size::KmSchedule;
    use amtl::coordinator::{Async, MtlProblem, RunConfig, Session};
    use amtl::optim::prox::RegularizerKind;

    let Some(pool) = pool(2) else { return };
    let mut rng = Rng::new(504);
    let ds = synthetic::lowrank_regression(&[100; 4], 50, 2, 0.1, &mut rng);
    let problem = MtlProblem::new(ds, RegularizerKind::Nuclear, 0.3, 0.5, &mut rng);
    let cfg = RunConfig {
        iters_per_node: 30,
        km: KmSchedule::fixed(0.9),
        record_every: 1_000_000,
        ..Default::default()
    };
    let r_native = Session::builder(&problem)
        .engine(Engine::Native)
        .config(cfg.clone())
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let r_pjrt = Session::builder(&problem)
        .engine(Engine::Pjrt)
        .pool(Some(&pool))
        .config(cfg)
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let f_native = problem.objective(&r_native.w_final);
    let f_pjrt = problem.objective(&r_pjrt.w_final);
    // Interleaving differs and PJRT is f32, but both must land at the same
    // optimization basin.
    assert!(
        (f_native - f_pjrt).abs() / f_native.max(1e-9) < 0.05,
        "native {f_native} vs pjrt {f_pjrt}"
    );
}

#[test]
fn static_data_uploaded_once_per_executor() {
    // Repeated steps must not re-upload X: verify by timing asymmetry —
    // the first call (compile + upload) is much slower than steady-state.
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(505);
    let ds = synthetic::lowrank_regression(&[5000], 50, 2, 0.1, &mut rng);
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();
    let w = rng.normal_vec(50);
    let t0 = std::time::Instant::now();
    pjrt[0].step(&w, 1e-4).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        pjrt[0].step(&w, 1e-4).unwrap();
    }
    let steady = t1.elapsed() / 5;
    assert!(
        steady < first,
        "steady {steady:?} should beat cold {first:?} (compile+upload amortized)"
    );
}

#[test]
fn missing_bucket_is_a_clean_error() {
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(506);
    // d=51 has no compiled artifact.
    let ds = synthetic::lowrank_regression(&[100], 51, 2, 0.1, &mut rng);
    let err = match make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks) {
        Ok(_) => panic!("expected missing-bucket error"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no artifact bucket"), "{msg}");
}

#[test]
fn pjrt_handles_tasks_larger_than_any_single_executor_cache_entry() {
    // Arc-shared static inputs across two task computes with same data are
    // still distinct static sets; both must work.
    let Some(pool) = pool(2) else { return };
    let mut rng = Rng::new(507);
    let ds = synthetic::lowrank_regression(&[200, 300], 50, 2, 0.1, &mut rng);
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();
    let w = rng.normal_vec(50);
    for c in pjrt.iter_mut() {
        let (u, obj) = c.step(&w, 1e-4).unwrap();
        assert_eq!(u.len(), 50);
        assert!(obj.is_finite());
    }
    drop(pjrt);
    drop(pool);
}

#[test]
fn pool_shutdown_is_clean() {
    let Some(pool) = pool(2) else { return };
    let p2 = pool.clone();
    drop(pool);
    // Last handle drop closes the channel; join must not hang.
    let _ = Arc::new(());
    drop(p2);
}

#[test]
fn pjrt_l21_prox_matches_native() {
    use amtl::coordinator::server::CentralServer;
    use amtl::coordinator::state::SharedState;
    use amtl::optim::prox::{prox_l21, Regularizer, RegularizerKind};

    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(600);
    // d=128 matches the prox_l21 artifact tile; T=5 pads to the t=8 bucket.
    let m = amtl::linalg::Mat::randn(128, 5, &mut rng);
    let state = std::sync::Arc::new(SharedState::new(&m));
    let lambda = 0.8;
    let eta = 0.25;
    let server = CentralServer::new(
        std::sync::Arc::clone(&state),
        Regularizer::new(RegularizerKind::L21, lambda),
        eta,
    )
    .with_pjrt_l21_prox(&pool)
    .expect("l21 artifact bucket");
    let got = server.prox_matrix();
    let mut want = m.clone();
    prox_l21(&mut want, eta * lambda);
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-5, "pjrt l21 prox diff {diff}");
}

#[test]
fn pjrt_l21_prox_rejects_wrong_regularizer() {
    use amtl::coordinator::server::CentralServer;
    use amtl::coordinator::state::SharedState;
    use amtl::optim::prox::{Regularizer, RegularizerKind};

    let Some(pool) = pool(1) else { return };
    let state = std::sync::Arc::new(SharedState::zeros(128, 4));
    let server = CentralServer::new(
        state,
        Regularizer::new(RegularizerKind::Nuclear, 0.5),
        0.1,
    );
    assert!(server.with_pjrt_l21_prox(&pool).is_err());
}

#[test]
fn full_pjrt_l21_amtl_run() {
    // The complete three-layer path on BOTH sides: forward steps and the
    // server's backward step all run through Pallas artifacts.
    use amtl::coordinator::server::CentralServer;
    use amtl::coordinator::state::SharedState;
    use amtl::coordinator::step_size::{KmSchedule, StepController};
    use amtl::coordinator::worker::{run_worker, TrajectorySink, WorkerCtx};
    use amtl::coordinator::metrics::Recorder;
    use amtl::net::{DelayModel, FaultModel};
    use amtl::optim::prox::{Regularizer, RegularizerKind};
    use amtl::transport::InProc;
    use std::sync::Arc;

    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(601);
    let ds = synthetic::lowrank_regression(&[100; 4], 128, 3, 0.2, &mut rng);
    let problem = amtl::coordinator::MtlProblem::new(
        ds,
        RegularizerKind::L21,
        0.3,
        0.5,
        &mut rng,
    );
    let state = Arc::new(SharedState::zeros(128, 4));
    let server = Arc::new(
        CentralServer::new(
            Arc::clone(&state),
            Regularizer::new(RegularizerKind::L21, 0.3),
            problem.eta,
        )
        .with_pjrt_l21_prox(&pool)
        .unwrap(),
    );
    let controller = Arc::new(StepController::new(KmSchedule::fixed(0.9), false, 4, 5));
    let recorder = Arc::new(Recorder::new(1_000_000));
    let mut computes = problem.build_computes(Engine::Pjrt, Some(&pool)).unwrap();
    std::thread::scope(|s| {
        for (t, c) in computes.iter_mut().enumerate() {
            let ctx = WorkerCtx {
                t,
                iters: 40,
                transport: Box::new(InProc::new(Arc::clone(&server))),
                controller: Arc::clone(&controller),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: std::time::Duration::from_millis(10),
                sink: Some(TrajectorySink {
                    recorder: Arc::clone(&recorder),
                    state: Arc::clone(server.state()),
                }),
                rng: Rng::new(700 + t as u64),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            s.spawn(move || run_worker(ctx, c.as_mut()).unwrap());
        }
    });
    let w = server.final_w();
    let f0 = problem.objective(&amtl::linalg::Mat::zeros(128, 4));
    let f1 = problem.objective(&w);
    assert!(f1 < 0.3 * f0, "full-PJRT l21 run: {f0} -> {f1}");
}

#[test]
fn pjrt_minibatch_step_matches_native_given_same_mask_statistics() {
    // The PJRT dyn-mask path must produce the same estimator as native:
    // with frac=1.0 the minibatch step IS the full step (weight 1/1).
    let Some(pool) = pool(1) else { return };
    let mut rng = Rng::new(602);
    let ds = synthetic::lowrank_regression(&[100], 50, 2, 0.1, &mut rng);
    let mut native = make_task_computes(Engine::Native, None, &ds.tasks).unwrap();
    let mut pjrt = make_task_computes(Engine::Pjrt, Some(&pool), &ds.tasks).unwrap();
    let w = rng.normal_vec(50);
    let mut rng_a = Rng::new(603);
    let mut rng_b = Rng::new(603);
    let (u_n, o_n) = native[0].step_minibatch(&w, 1e-3, 1.0, &mut rng_a).unwrap();
    let (u_p, o_p) = pjrt[0].step_minibatch(&w, 1e-3, 1.0, &mut rng_b).unwrap();
    let max_diff = u_n
        .iter()
        .zip(&u_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-3, "diff {max_diff}");
    assert!((o_n - o_p).abs() / o_n.max(1.0) < 1e-3);
    // And a genuinely stochastic PJRT step at frac=0.3 stays finite/sane.
    let (u_s, o_s) = pjrt[0].step_minibatch(&w, 1e-3, 0.3, &mut rng_b).unwrap();
    assert!(u_s.iter().all(|v| v.is_finite()));
    assert!(o_s.is_finite() && o_s >= 0.0);
}
