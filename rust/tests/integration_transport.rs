//! Integration tests for the transport layer: loopback-TCP sessions must
//! reach the same answers as in-proc sessions, under every schedule, and
//! the two-process deployment shape (`--serve` / `--node`) must converge
//! when exercised as server + independent TCP worker clients.

use amtl::coordinator::server::CentralServer;
use amtl::coordinator::state::SharedState;
use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{Async, MtlProblem, Schedule, SemiSync, Session, Synchronized};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::transport::{TcpClient, TcpOptions, TcpServer, TransportKind};
use amtl::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

fn run_with(
    p: &MtlProblem,
    kind: TransportKind,
    schedule: impl Schedule + 'static,
    iters: usize,
) -> amtl::coordinator::RunResult {
    Session::builder(p)
        .iters_per_node(iters)
        .eta_k(0.9)
        .record_every(1_000_000)
        .transport(kind)
        .schedule(schedule)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

// ------------------------------------------------ session over loopback

#[test]
fn tcp_session_is_bit_identical_to_inproc_on_one_task() {
    // One task ⇒ a deterministic fetch/commit sequence ⇒ serialization
    // must be exactly invertible: same bits out of either transport.
    let p = lowrank_problem(830, 1, 40, 6, 0.2);
    let a = run_with(&p, TransportKind::InProc, Async, 30);
    let b = run_with(&p, TransportKind::Tcp, Async, 30);
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.prox_count, b.prox_count);
    assert_eq!(a.v_final, b.v_final, "V bit-identical across transports");
    assert_eq!(a.w_final, b.w_final, "W bit-identical across transports");
}

#[test]
fn tcp_async_session_converges_like_inproc() {
    // The acceptance check: same seed, same budget — the TCP run must land
    // at the same objective (within the tolerance that concurrent
    // interleaving already implies for in-proc runs).
    let p = lowrank_problem(831, 4, 40, 8, 0.3);
    let f_inproc = p.objective(&run_with(&p, TransportKind::InProc, Async, 150).w_final);
    let f_tcp = p.objective(&run_with(&p, TransportKind::Tcp, Async, 150).w_final);
    assert!(
        (f_tcp - f_inproc).abs() / f_inproc.max(1e-9) < 0.05,
        "tcp {f_tcp} vs inproc {f_inproc}"
    );
}

#[test]
fn tcp_synchronized_session_matches_inproc_exactly() {
    // Synchronized rounds are deterministic in value: the transport must
    // not move the objective at all.
    let p = lowrank_problem(832, 3, 30, 6, 0.2);
    let a = run_with(&p, TransportKind::InProc, Synchronized, 25);
    let b = run_with(&p, TransportKind::Tcp, Synchronized, 25);
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.updates_per_node, b.updates_per_node);
    let (fa, fb) = (p.objective(&a.w_final), p.objective(&b.w_final));
    assert!((fa - fb).abs() < 1e-9, "sync inproc {fa} vs tcp {fb}");
}

#[test]
fn tcp_semisync_session_runs_full_budget() {
    let p = lowrank_problem(833, 3, 30, 6, 0.2);
    let r = run_with(&p, TransportKind::Tcp, SemiSync { staleness_bound: 2 }, 40);
    assert_eq!(r.updates, 120);
    assert_eq!(r.updates_per_node, vec![40; 3]);
    let f0 = p.objective(&p.prox_map(&amtl::linalg::Mat::zeros(6, 3)));
    let f1 = p.objective(&r.w_final);
    assert!(f1 < 0.5 * f0, "semisync over tcp: {f0} -> {f1}");
}

#[test]
fn tcp_session_supports_faults_like_inproc() {
    let p = lowrank_problem(834, 3, 20, 5, 0.2);
    let r = Session::builder(&p)
        .iters_per_node(20)
        .faults(FaultModel::CrashAfter { node: 1, after: 3 })
        .transport(TransportKind::Tcp)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.crashed_nodes, vec![1]);
    assert_eq!(r.updates_per_node, vec![20, 3, 20]);
}

// ------------------------------------- two-process shape over loopback

/// The `--serve` / `--node` deployment, compressed into one test process:
/// a standalone TCP server wrapping its own state, and one independent
/// client-driven worker per task — each holding only its task's compute,
/// exactly like `amtl --node <t>` — connected over real sockets.
#[test]
fn node_style_tcp_cluster_converges_to_inproc_objective() {
    let p = lowrank_problem(835, 3, 40, 6, 0.2);
    let iters = 120;

    // Reference: plain in-proc session, same seeds.
    let f_ref = p.objective(&run_with(&p, TransportKind::InProc, Async, iters).w_final);

    // "serve" side: state + central server + listener.
    let state = Arc::new(SharedState::zeros(p.d(), p.t()));
    let server = Arc::new(CentralServer::new(Arc::clone(&state), p.regularizer(), p.eta));
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), None).unwrap();
    let addr = handle.addr();

    // "node" side: one worker per task, own compute, own connection, own
    // RNG stream (forked like the session forks them).
    let mut computes = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let controller = Arc::new(StepController::new(KmSchedule::fixed(0.9), false, p.t(), 5));
    let mut root = Rng::new(7);
    std::thread::scope(|s| {
        for (t, compute) in computes.iter_mut().enumerate() {
            let client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
            let ctx = WorkerCtx {
                t,
                iters,
                transport: Box::new(client),
                controller: Arc::clone(&controller),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng: root.fork(t as u64),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            s.spawn(move || {
                let stats = run_worker(ctx, compute.as_mut()).unwrap();
                assert_eq!(stats.updates, iters as u64);
            });
        }
    });
    handle.shutdown();

    assert_eq!(state.version(), (p.t() * iters) as u64);
    let w = server.final_w();
    let f_cluster = p.objective(&w);
    assert!(
        (f_cluster - f_ref).abs() / f_ref.max(1e-9) < 0.05,
        "cluster {f_cluster} vs in-proc {f_ref}"
    );
}
