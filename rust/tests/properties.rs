//! Property-based tests (via the in-tree `util::proptest` mini-framework)
//! over the numerical operators and coordinator invariants that the AMTL
//! convergence theory rests on.

use amtl::coordinator::state::SharedState;
use amtl::linalg::Mat;
use amtl::optim::losses::{Loss, RowMat};
use amtl::optim::formulation::{self, FormulationSpec, FORMULATIONS};
use amtl::optim::prox::{prox_l21, NuclearProx, Regularizer, RegularizerKind};
use amtl::optim::svd::Svd;
use amtl::optim::SharedProx;
use amtl::util::proptest::forall;
use amtl::util::Rng;

fn mat_from(v: &[f64], rows: usize) -> Mat {
    let cols = v.len() / rows;
    Mat::from_fn(rows, cols, |r, c| v[c * rows + r])
}

// ----------------------------------------------------------------- SVD

#[test]
fn prop_svd_reconstructs() {
    forall(
        "jacobi svd reconstructs A",
        40,
        |g| {
            let rows = g.usize_in(1, 12).max(1);
            let cols = g.usize_in(1, 12).max(1);
            (g.normal_vec(rows * cols), rows)
        },
        |(v, rows)| {
            let a = mat_from(v, *rows);
            let s = Svd::jacobi(&a);
            s.reconstruct().max_abs_diff(&a) < 1e-8
        },
    );
}

#[test]
fn prop_svd_nuclear_norm_bounds_frobenius() {
    // ‖A‖_F ≤ ‖A‖_* ≤ √rank·‖A‖_F.
    forall(
        "nuclear vs frobenius",
        40,
        |g| {
            let rows = g.usize_in(1, 10).max(1);
            let cols = g.usize_in(1, 10).max(1);
            (g.normal_vec(rows * cols), rows)
        },
        |(v, rows)| {
            let a = mat_from(v, *rows);
            let s = Svd::jacobi(&a);
            let nuc = s.nuclear_norm();
            let fro = a.frobenius_norm();
            let k = s.sigma.len() as f64;
            nuc >= fro - 1e-9 && nuc <= k.sqrt() * fro + 1e-9
        },
    );
}

#[test]
fn prop_svt_reduces_nuclear_norm_by_at_most_k_tau() {
    forall(
        "svt shrinkage bound",
        30,
        |g| {
            let rows = g.usize_in(2, 8).max(2);
            (g.normal_vec(rows * 4), rows, g.f64_in(0.0, 2.0))
        },
        |(v, rows, tau)| {
            let a = mat_from(v, *rows);
            let before = Svd::jacobi(&a);
            let after = Svd::jacobi(&before.shrink_reconstruct(*tau));
            let want: f64 = before.sigma.iter().map(|s| (s - tau).max(0.0)).sum();
            (after.nuclear_norm() - want).abs() < 1e-7
        },
    );
}

// ----------------------------------------------------------------- prox

#[test]
fn prop_prox_l21_output_rows_shrink() {
    forall(
        "l21 row norms shrink by exactly tau",
        50,
        |g| (g.normal_vec(24), g.f64_in(0.0, 3.0)),
        |(v, tau)| {
            let a = mat_from(v, 6);
            let mut w = a.clone();
            prox_l21(&mut w, *tau);
            (0..6).all(|r| {
                let before: f64 = (0..4).map(|c| a.get(r, c).powi(2)).sum::<f64>().sqrt();
                let after: f64 = (0..4).map(|c| w.get(r, c).powi(2)).sum::<f64>().sqrt();
                (after - (before - tau).max(0.0)).abs() < 1e-10
            })
        },
    );
}

#[test]
fn prop_prox_is_idempotent_like_for_l1() {
    // prox_τ(prox_τ(x)) shrinks again — but prox of the *same point* twice
    // equals shrinking by 2τ for L1 (check the identity).
    forall(
        "double soft threshold = 2tau threshold",
        50,
        |g| (g.normal_vec(10), g.f64_in(0.0, 1.0)),
        |(v, tau)| {
            let a = mat_from(v, 5);
            let mut twice = a.clone();
            let mut reg = Regularizer::new(RegularizerKind::L1, 1.0);
            reg.prox(&mut twice, *tau);
            reg.prox(&mut twice, *tau);
            let mut once = a.clone();
            reg.prox(&mut once, 2.0 * tau);
            twice.max_abs_diff(&once) < 1e-12
        },
    );
}

// --------------------------------------------------------------- losses

#[test]
fn prop_squared_gradient_is_linear_in_residual() {
    // ∇ at w scaled toward the interpolator shrinks proportionally.
    forall(
        "grad linearity",
        30,
        |g| {
            let n = g.usize_in(2, 20).max(2);
            (g.normal_vec(n * 3), g.normal_vec(3))
        },
        |(xv, w_star)| {
            let n = xv.len() / 3;
            let mut x = RowMat::zeros(n, 3);
            x.data.copy_from_slice(xv);
            let y: Vec<f64> = (0..n)
                .map(|i| x.row(i).iter().zip(w_star).map(|(a, b)| a * b).sum())
                .collect();
            let mask = vec![1.0; n];
            // At w*, gradient is 0; at w*+delta, gradient = 2XᵀX·delta — so
            // halving delta halves the gradient.
            let delta = [0.5, -1.0, 0.25];
            let w1: Vec<f64> = w_star.iter().zip(delta).map(|(w, d)| w + d).collect();
            let w2: Vec<f64> = w_star.iter().zip(delta).map(|(w, d)| w + 0.5 * d).collect();
            let (g1, _) = Loss::Squared.grad_obj(&x, &y, &w1, &mask);
            let (g2, _) = Loss::Squared.grad_obj(&x, &y, &w2, &mask);
            g1.iter().zip(&g2).all(|(a, b)| (a - 2.0 * b).abs() < 1e-6 * a.abs().max(1.0))
        },
    );
}

#[test]
fn prop_logistic_gradient_bounded_by_data_scale() {
    // ‖∇ℓ‖∞ ≤ Σ_i |x_ik| since |σ(z)−y| ≤ 1.
    forall(
        "logistic grad bound",
        30,
        |g| {
            let n = g.usize_in(1, 15).max(1);
            (g.normal_vec(n * 4), g.normal_vec(4))
        },
        |(xv, w)| {
            let n = xv.len() / 4;
            let mut x = RowMat::zeros(n, 4);
            x.data.copy_from_slice(xv);
            let y: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
            let mask = vec![1.0; n];
            let (g_vec, _) = Loss::Logistic.grad_obj(&x, &y, w, &mask);
            (0..4).all(|k| {
                let bound: f64 = (0..n).map(|i| x.row(i)[k].abs()).sum();
                g_vec[k].abs() <= bound + 1e-9
            })
        },
    );
}

// ----------------------------------------------------- coordinator state

#[test]
fn prop_km_update_contracts_toward_u() {
    // After v += step(u−v) with step ∈ (0,1], distance to u shrinks by
    // exactly (1−step).
    forall(
        "km contraction factor",
        50,
        |g| {
            let v = g.normal_vec(6);
            let u = g.normal_vec(6);
            ((v, u), g.f64_in(0.05, 1.0))
        },
        |((v, u), step)| {
            let mut m = Mat::zeros(6, 1);
            m.col_mut(0).copy_from_slice(v);
            let s = SharedState::new(&m);
            let before: f64 = v.iter().zip(u).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt();
            s.km_update(0, u, *step);
            let got = s.read_col(0);
            let after: f64 = got.iter().zip(u).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt();
            (after - (1.0 - step) * before).abs() < 1e-9 * before.max(1.0)
        },
    );
}

#[test]
fn prop_version_counter_equals_total_updates() {
    // Routing invariant: the global version is exactly the sum of per-block
    // updates, regardless of the interleaving pattern.
    forall(
        "version accounting",
        20,
        |g| {
            let t = g.usize_in(1, 6).max(1);
            let per_block: Vec<f64> = (0..t).map(|_| g.usize_in(0, 40) as f64).collect();
            per_block
        },
        |per_block| {
            let t = per_block.len();
            let s = std::sync::Arc::new(SharedState::zeros(3, t));
            std::thread::scope(|scope| {
                for (b, count) in per_block.iter().enumerate() {
                    let s = std::sync::Arc::clone(&s);
                    let count = *count as usize;
                    scope.spawn(move || {
                        let mut rng = Rng::new(b as u64);
                        for _ in 0..count {
                            let u = rng.normal_vec(3);
                            s.km_update(b, &u, 0.5);
                        }
                    });
                }
            });
            let want: u64 = per_block.iter().map(|c| *c as u64).sum();
            s.version() == want
                && (0..t).all(|b| s.col_version(b) == per_block[b] as u64)
        },
    );
}

#[test]
fn prop_backward_forward_iteration_is_nonexpansive() {
    // The composed map T(v) = v + η_k((I−η∇f)Prox(v) − v) on a 1-task
    // problem is non-expansive for η ∈ (0, 2/L): distances never grow.
    forall(
        "backward-forward nonexpansive",
        25,
        |g| {
            let n = g.usize_in(4, 20).max(4);
            (g.normal_vec(n * 3 + n), g.normal_vec(3), g.normal_vec(3))
        },
        |(data, v1, v2)| {
            let n = (data.len() - 0) / 4; // n*3 features + n labels
            let (xv, yv) = data.split_at(n * 3);
            let mut x = RowMat::zeros(n, 3);
            x.data.copy_from_slice(xv);
            let y = yv.to_vec();
            let mask = vec![1.0; n];
            let mut rng = Rng::new(9);
            let l = amtl::optim::lipschitz::task_lipschitz(Loss::Squared, &x, &mut rng) * 1.001;
            let eta = 1.0 / l;
            let reg = Regularizer::new(RegularizerKind::L1, 0.3);
            let eta_k = 0.8;
            let apply = |v: &[f64]| -> Vec<f64> {
                // backward
                let mut m = Mat::zeros(3, 1);
                m.col_mut(0).copy_from_slice(v);
                reg.clone_box().prox(&mut m, eta);
                let w_hat = m.col(0);
                // forward
                let (u, _) = Loss::Squared.step(&x, &y, w_hat, &mask, eta);
                // KM
                v.iter().zip(&u).map(|(vi, ui)| vi + eta_k * (ui - vi)).collect()
            };
            let t1 = apply(v1);
            let t2 = apply(v2);
            let d_before: f64 = v1.iter().zip(v2).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt();
            let d_after: f64 = t1.iter().zip(&t2).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt();
            d_after <= d_before * (1.0 + 1e-9) + 1e-12
        },
    );
}

// ------------------------------------------------- parallel linalg kernels

#[test]
fn prop_parallel_matmul_bitwise_equals_serial() {
    // The pool-blocked matmul partitions the output but keeps the serial
    // per-column loop order, so results must be *bitwise* identical for
    // arbitrary f64 inputs — not merely close.
    use amtl::linalg::par;
    use amtl::runtime::WorkerPool;
    let pool = WorkerPool::new(4);
    forall(
        "parallel matmul == serial matmul (bitwise)",
        40,
        |g| {
            let m = g.usize_in(1, 24).max(1);
            let k = g.usize_in(1, 24).max(1);
            let n = g.usize_in(1, 24).max(1);
            ((g.normal_vec(m * k), g.normal_vec(k * n)), (m, k, n))
        },
        |((av, bv), (m, k, n))| {
            // Shrink candidates may break the length/shape relation.
            if av.len() != m * k || bv.len() != k * n {
                return true;
            }
            let a = mat_from(av, *m);
            let b = mat_from(bv, *k);
            let serial = par::matmul_serial(&a, &b);
            let parallel = par::matmul_on(Some(&pool), &a, &b);
            serial == parallel && parallel.rows() == *m && parallel.cols() == *n
        },
    );
}

#[test]
fn prop_parallel_gram_bitwise_equals_serial() {
    use amtl::linalg::par;
    use amtl::runtime::WorkerPool;
    let pool = WorkerPool::new(3);
    forall(
        "parallel gram == serial gram (bitwise)",
        40,
        |g| {
            let m = g.usize_in(1, 30).max(1);
            let n = g.usize_in(1, 16).max(1);
            (g.normal_vec(m * n), m)
        },
        |(av, m)| {
            if *m == 0 || av.len() % m != 0 {
                return true;
            }
            let a = mat_from(av, *m);
            par::gram_serial(&a) == par::gram_on(Some(&pool), &a)
        },
    );
}

// --------------------------------------------------- persist codec / WAL

#[test]
fn prop_snapshot_roundtrips_bitwise() {
    use amtl::coordinator::server::CentralServer;
    use amtl::persist::{Checkpointer, PersistConfig, ServerSnapshot};
    forall(
        "server snapshot encode/decode is the identity",
        15,
        |g| {
            let d = g.usize_in(1, 8).max(1);
            let t = g.usize_in(1, 5).max(1);
            let commits = g.usize_in(0, 12);
            ((g.normal_vec(d * t), d), (t, commits))
        },
        |((v, d), (t, commits))| {
            // Build a durable server, drive a few commit/prox rounds so
            // every snapshot section is non-trivial, then round-trip the
            // latest snapshot through bytes.
            let dir = std::env::temp_dir().join(format!(
                "amtl_prop_snap_{}_{d}x{t}_{commits}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&dir).ok();
            let m = mat_from(v, *d);
            let state = std::sync::Arc::new(SharedState::new(&m));
            let reg = Box::new(NuclearProx::new(0.3).with_online(&m).with_resvd_every(4));
            let cp = std::sync::Arc::new(
                Checkpointer::create(PersistConfig::new(&dir, 3)).unwrap(),
            );
            let srv = CentralServer::new(state, reg, 0.2)
                .with_checkpointer(cp)
                .unwrap();
            let mut rng = Rng::new((*commits as u64 + 1) * 31);
            for i in 0..*commits {
                let u = rng.normal_vec(*d);
                srv.commit_update(i % t, (i / t) as u64, &u, 0.6).unwrap();
                let _ = srv.prox_matrix();
            }
            if let Some(cp) = srv.checkpointer() {
                cp.checkpoint_now(&srv).unwrap();
            }
            // Round-trip the newest snapshot file through the codec.
            let newest = std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.extension().map(|x| x == "amtls").unwrap_or(false))
                .max()
                .unwrap();
            let snap = ServerSnapshot::read_file(&newest).unwrap();
            let mut buf = Vec::new();
            snap.encode(&mut buf).unwrap();
            let back = ServerSnapshot::decode(&mut std::io::Cursor::new(&buf)).unwrap();
            std::fs::remove_dir_all(&dir).ok();
            back == snap
        },
    );
}

#[test]
fn prop_wal_replay_equals_live_run_bitwise() {
    use amtl::coordinator::server::CentralServer;
    use amtl::persist::{recover, Checkpointer, PersistConfig};
    forall(
        "snapshot + wal replay reproduces the live server bitwise",
        10,
        |g| {
            let d = g.usize_in(2, 8).max(2);
            let t = g.usize_in(1, 4).max(1);
            let commits = g.usize_in(1, 15).max(1);
            let stride = g.usize_in(1, 6).max(1);
            ((d, t), (commits, stride))
        },
        |((d, t), (commits, stride))| {
            let dir = std::env::temp_dir().join(format!(
                "amtl_prop_replay_{}_{d}x{t}_{commits}_{stride}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&dir).ok();
            let mut rng = Rng::new((*commits * 7 + *stride) as u64);
            let m = Mat::randn(*d, *t, &mut rng);
            let state = std::sync::Arc::new(SharedState::new(&m));
            let reg = Box::new(NuclearProx::new(0.3).with_online(&m).with_resvd_every(3));
            let cp = std::sync::Arc::new(
                Checkpointer::create(PersistConfig::new(&dir, *stride as u64)).unwrap(),
            );
            let srv = CentralServer::new(state, reg, 0.2)
                .with_checkpointer(cp)
                .unwrap();
            for i in 0..*commits {
                let u = rng.normal_vec(*d);
                srv.commit_update(i % t, (i / t) as u64, &u, 0.6).unwrap();
                let _ = srv.prox_matrix();
            }
            let rec = recover(PersistConfig::new(&dir, *stride as u64)).unwrap();
            let ok = rec.server.state().snapshot() == srv.state().snapshot()
                && rec.server.final_w() == srv.final_w();
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

// ----------------------------------------------------- net delay models

/// Decode a generated `(variant, params)` pair into a `DelayModel`.
/// Durations are built from |param| clamped to ≤ 10ms so properties stay
/// fast; variant 4 is `PerNode` with one leaf per param (possibly zero
/// leaves when `params` shrinks to empty).
fn delay_model_from(variant: usize, params: &[f64]) -> amtl::net::DelayModel {
    use amtl::net::DelayModel;
    use std::time::Duration;
    let dur = |i: usize| {
        Duration::from_secs_f64(params.get(i).map(|x| x.abs().min(0.01)).unwrap_or(0.0))
    };
    match variant % 5 {
        0 => DelayModel::None,
        1 => DelayModel::OffsetJitter { offset: dur(0), jitter: dur(1) },
        2 => DelayModel::OffsetExp { offset: dur(0), mean: dur(1) },
        3 => DelayModel::Poisson { mean: dur(0) },
        _ => DelayModel::PerNode {
            per_node: params
                .iter()
                .map(|x| {
                    Box::new(DelayModel::OffsetJitter {
                        offset: Duration::from_secs_f64(x.abs().min(0.01)),
                        jitter: Duration::from_secs_f64(x.abs().min(0.005)),
                    })
                })
                .collect(),
        },
    }
}

#[test]
fn prop_delay_models_are_seed_deterministic() {
    // Same seed → bitwise-identical sample sequence, for every variant
    // and any node index. This is what makes a chaos storm reproducible
    // from its printed seed.
    forall(
        "delay sampling is a pure function of (model, seed)",
        60,
        |g| {
            let len = g.usize_in(0, 4);
            ((g.usize_in(0, 4), g.normal_vec(len)), g.usize_in(0, 0xFFFF))
        },
        |((variant, params), seed)| {
            let m = delay_model_from(*variant, params);
            let mut a = Rng::new(*seed as u64);
            let mut b = Rng::new(*seed as u64);
            (0..50).all(|i| {
                let node = i % 7;
                m.sample(node, &mut a).duration == m.sample(node, &mut b).duration
            })
        },
    );
}

#[test]
fn prop_delay_samples_respect_offset_floor_and_finiteness() {
    // Every sample is a finite, non-negative duration, and the offset
    // variants never sample below their offset.
    forall(
        "delay samples finite and >= offset",
        60,
        |g| {
            let len = g.usize_in(0, 4);
            ((g.usize_in(0, 4), g.normal_vec(len)), g.usize_in(0, 64))
        },
        |((variant, params), node)| {
            use amtl::net::DelayModel;
            let m = delay_model_from(*variant, params);
            let mut rng = Rng::new(991);
            let floor = match &m {
                DelayModel::OffsetJitter { offset, .. }
                | DelayModel::OffsetExp { offset, .. } => *offset,
                _ => std::time::Duration::ZERO,
            };
            (0..100).all(|_| {
                let d = m.sample(*node, &mut rng).duration;
                d >= floor && d.as_secs_f64().is_finite()
            }) && m.mean(*node).as_secs_f64().is_finite()
        },
    );
}

#[test]
fn prop_per_node_never_panics_for_any_shape() {
    // `PerNode` must tolerate any (table length, node index) combination:
    // empty tables, single entries, nested empty tables, and node indices
    // far beyond the table length — shrink-adjacent shapes a generated
    // chaos plan can legitimately produce.
    forall(
        "PerNode indexing total over all shapes",
        80,
        |g| {
            let len = g.usize_in(0, 3);
            (g.normal_vec(len), g.usize_in(0, 500), g.usize_in(0, 1))
        },
        |(params, node, nest_empty)| {
            use amtl::net::DelayModel;
            let mut per_node: Vec<Box<DelayModel>> = params
                .iter()
                .map(|x| {
                    Box::new(DelayModel::OffsetJitter {
                        offset: std::time::Duration::from_secs_f64(x.abs().min(0.01)),
                        jitter: std::time::Duration::ZERO,
                    })
                })
                .collect();
            if *nest_empty == 1 {
                // An empty table nested inside a non-empty one.
                per_node.push(Box::new(DelayModel::PerNode { per_node: vec![] }));
            }
            let empty = per_node.is_empty();
            let m = DelayModel::PerNode { per_node };
            let mut rng = Rng::new(17);
            let s = m.sample(*node, &mut rng).duration;
            let mean = m.mean(*node);
            // Empty tables degrade to zero delay instead of panicking.
            (!empty || (s == std::time::Duration::ZERO && mean == std::time::Duration::ZERO))
                && s.as_secs_f64().is_finite()
        },
    );
}

// ------------------------------------------------ formulation registry

/// Resolve every registered formulation at strength `lambda` over `t`
/// tasks (the registry is the single source of truth for "every
/// regularizer" — a formulation added later is covered automatically).
fn all_formulations(lambda: f64, t: usize) -> Vec<Box<dyn SharedProx>> {
    FORMULATIONS
        .iter()
        .map(|info| {
            let spec = FormulationSpec::parse(info.name).unwrap();
            formulation::resolve(&spec, lambda, 1.5, t).unwrap()
        })
        .collect()
}

#[test]
fn prop_every_registered_prox_nonexpansive() {
    // ‖prox(a) − prox(b)‖_F ≤ ‖a − b‖_F for every formulation in the
    // registry — the property Theorem 1's operator analysis rests on,
    // checked against the same registry the CLI and persist layer use.
    forall(
        "registered prox nonexpansive",
        25,
        |g| (g.normal_vec(12), g.normal_vec(12), g.f64_in(0.05, 1.5)),
        |(a, b, eta)| {
            let ma = mat_from(a, 3);
            let mb = mat_from(b, 3);
            let before = ma.add_scaled(-1.0, &mb).frobenius_norm();
            all_formulations(0.6, 4).into_iter().all(|mut reg| {
                let mut pa = ma.clone();
                let mut pb = mb.clone();
                reg.prox(&mut pa, *eta);
                reg.prox(&mut pb, *eta);
                let after = pa.add_scaled(-1.0, &pb).frobenius_norm();
                assert!(
                    after <= before + 1e-9,
                    "{}: prox expanded {before} -> {after}",
                    reg.id()
                );
                true
            })
        },
    );
}

#[test]
fn prop_every_registered_prox_satisfies_moreau_optimality() {
    // prox(v) minimizes ½‖z−v‖² + η·λg(z): its objective must not exceed
    // the objective at v itself or at random candidate points. This is
    // the formulation-agnostic correctness check (soft-threshold families
    // and matrix-coupled families alike).
    forall(
        "registered prox minimizes the Moreau objective",
        20,
        |g| (g.normal_vec(12), g.normal_vec(12), g.f64_in(0.05, 1.0)),
        |(v, z, eta)| {
            let mv = mat_from(v, 3);
            let mz = mat_from(z, 3);
            all_formulations(0.8, 4).into_iter().all(|mut reg| {
                let mut p = mv.clone();
                reg.prox(&mut p, *eta);
                let moreau = |cand: &Mat| {
                    0.5 * cand.add_scaled(-1.0, &mv).frobenius_norm().powi(2)
                        + eta * reg.value(cand)
                };
                let at_prox = moreau(&p);
                assert!(
                    at_prox <= moreau(&mv) + 1e-9,
                    "{}: prox objective above the anchor point",
                    reg.id()
                );
                assert!(
                    at_prox <= moreau(&mz) + 1e-9,
                    "{}: prox objective above a random candidate",
                    reg.id()
                );
                true
            })
        },
    );
}

#[test]
fn prop_separable_prox_commutes_with_column_slicing() {
    // The sharded server's load-bearing contract: a formulation that
    // reports `is_separable()` must prox a column subset to exactly the
    // columns the full-matrix prox produces (bitwise) — that is why
    // separable shards can run the real regularizer on their own slice
    // and still merge to the single-server model. Registry-driven, so a
    // formulation added later is covered automatically; the expectation
    // table pins today's split (only the elementwise family is
    // column-separable — l21 couples columns through row norms, mean
    // through the task centroid, nuclear/graph through the spectrum and
    // Laplacian).
    let expect_separable = |name: &str| matches!(name, "l1" | "elasticnet" | "none");
    for info in FORMULATIONS.iter() {
        let spec = FormulationSpec::parse(info.name).unwrap();
        let reg = formulation::resolve(&spec, 0.6, 1.5, 6).unwrap();
        assert_eq!(
            reg.is_separable(),
            expect_separable(reg.id()),
            "unexpected is_separable() for {}",
            reg.id()
        );
    }
    forall(
        "separable prox == column slice of full prox (bitwise)",
        30,
        |g| {
            let lo = g.usize_in(0, 5);
            ((g.normal_vec(4 * 6), g.f64_in(0.05, 1.2)), (lo, g.usize_in(lo + 1, 6)))
        },
        |((v, eta), (lo, hi))| {
            if *lo >= *hi || *hi > 6 || v.len() != 24 {
                return true; // shrink candidates may break the shape
            }
            let full_in = mat_from(v, 4);
            for info in FORMULATIONS.iter() {
                let spec = FormulationSpec::parse(info.name).unwrap();
                let mut full_reg = formulation::resolve(&spec, 0.6, 1.5, 6).unwrap();
                if !full_reg.is_separable() {
                    continue;
                }
                let mut full = full_in.clone();
                full_reg.prox(&mut full, *eta);
                let mut slice = Mat::zeros(4, hi - lo);
                for (j, t) in (*lo..*hi).enumerate() {
                    slice.set_col(j, full_in.col(t));
                }
                // A fresh instance over only the slice's columns — the
                // shard-shaped deployment the equality must survive.
                let mut slice_reg =
                    formulation::resolve(&spec, 0.6, 1.5, hi - lo).unwrap();
                slice_reg.prox(&mut slice, *eta);
                for (j, t) in (*lo..*hi).enumerate() {
                    assert_eq!(
                        slice.col(j),
                        full.col(t),
                        "{}: column {t} of the sliced prox diverged",
                        full_reg.id()
                    );
                }
            }
            true
        },
    );
}

#[test]
fn prop_sparsity_family_prox_is_soft_threshold_on_diagonals() {
    // On a diagonal input W = diag(σ) the nuclear, ℓ2,1 and ℓ1 proxes all
    // collapse to the same closed form — elementwise soft-thresholding of
    // the diagonal (singular values = row norms = |entries|), and the
    // elastic net is that shrunk by 1/(1+τγ). This pins each prox to its
    // textbook formula, not just to qualitative properties.
    let soft = |x: f64, tau: f64| {
        if x > tau {
            x - tau
        } else if x < -tau {
            x + tau
        } else {
            0.0
        }
    };
    forall(
        "diagonal prox = soft threshold",
        30,
        |g| (g.normal_vec(4), g.f64_in(0.05, 1.2)),
        |(diag, eta)| {
            let lambda = 0.7;
            let tau = eta * lambda;
            let mut w0 = Mat::zeros(4, 4);
            for (i, x) in diag.iter().enumerate() {
                w0.set(i, i, *x);
            }
            for kind in [
                RegularizerKind::Nuclear,
                RegularizerKind::L21,
                RegularizerKind::L1,
                RegularizerKind::ElasticNet,
            ] {
                let mut reg = Regularizer::new(kind, lambda);
                let mut w = w0.clone();
                reg.prox(&mut w, *eta);
                let scale = if kind == RegularizerKind::ElasticNet {
                    1.0 / (1.0 + tau) // γ = 1 from the classic factory
                } else {
                    1.0
                };
                for i in 0..4 {
                    for j in 0..4 {
                        let want =
                            if i == j { soft(diag[i], tau) * scale } else { 0.0 };
                        assert!(
                            (w.get(i, j) - want).abs() < 1e-8,
                            "{:?} diag prox ({i},{j}): got {} want {want}",
                            kind,
                            w.get(i, j)
                        );
                    }
                }
            }
            true
        },
    );
}
