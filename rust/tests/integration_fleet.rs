//! Integration tests for fleet observability: worker metric reports fan
//! in through the trainer over the real wire protocol (`PushMetrics` →
//! NODE rows in the `FetchMetrics` answer), the collector merges
//! endpoint rows with fanned-in NODE rows so fleet-wide histogram counts
//! equal the sum of per-process counts, and the health rules evaluate
//! evidence polled over real sockets.

use amtl::coordinator::{MtlProblem, RunConfig};
use amtl::data::synthetic;
use amtl::obs::{Collector, HealthRules, Histogram};
use amtl::optim::prox::RegularizerKind;
use amtl::serve::PredictClient;
use amtl::transport::wire::MetricsReport;
use amtl::transport::{TcpClient, TcpOptions, TcpServer, Transport};
use amtl::util::Rng;
use std::sync::Arc;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(5);

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

/// A worker-side report as `push_node_metrics` would assemble it, with
/// known contents so the fan-in can be asserted exactly.
fn node_report(updates: u64, commit_us: &[u64]) -> MetricsReport {
    let h = Histogram::new();
    for &s in commit_us {
        h.record(s);
    }
    MetricsReport {
        role: MetricsReport::ROLE_NODE,
        uptime_ms: 1234,
        counters: vec![("node.updates".into(), updates)],
        gauges: vec![],
        hists: vec![("node.commit_us".into(), h.snapshot())],
        nodes: vec![],
    }
}

#[test]
fn node_metrics_fan_in_and_merge_across_the_fleet() {
    // Two workers push their metric reports over the wire; the trainer's
    // FetchMetrics answer fans them in as NODE rows; a collector fed
    // that answer flattens the rows and merges histograms so the
    // fleet-wide count equals the sum of the per-process counts.
    let p = lowrank_problem(9100, 2, 40, 6, 0.25);
    let cfg = RunConfig { iters_per_node: 4, record_every: 1_000_000, ..Default::default() };
    let (_state, server, recorder) = cfg.build_server(&p).unwrap();
    let mut handle =
        TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), Some(recorder)).unwrap();
    let addr = handle.addr();

    // Two "worker processes": each drives one real commit and pushes one
    // metrics report across the framed protocol.
    let mut rng = Rng::new(12);
    for t in 0..2usize {
        let mut client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
        let _w = client.fetch_prox_col(t).unwrap();
        let u = rng.normal_vec(p.d());
        client.push_update(t, 0, 0.5, &u).unwrap();
        client
            .push_metrics(t, node_report(t as u64 + 3, &[100 * (t as u64 + 1), 250]))
            .unwrap();
    }

    // The trainer's FetchMetrics frame carries both NODE rows, exactly
    // as pushed, at fan-in depth 1.
    let mut mc = PredictClient::connect(addr, TIMEOUT).unwrap();
    let report = mc.metrics().unwrap();
    assert_eq!(report.nodes.len(), 2, "one NODE row per worker");
    for (t, sub) in &report.nodes {
        assert_eq!(sub.role_name(), "node");
        assert_eq!(sub.counter("node.updates"), Some(*t as u64 + 3));
        assert_eq!(sub.hist("node.commit_us").unwrap().count(), 2);
        assert!(sub.nodes.is_empty(), "fan-in is depth 1");
    }
    mc.close().unwrap();
    handle.shutdown();

    // Collector arithmetic over the wire-fed report: the merged
    // histogram count equals the sum over all rows' own counts, and
    // counters sum across rows.
    let mut c = Collector::new(&["trainer"]);
    c.observe(0, 0, Some(report));
    let rows = c.rows();
    assert_eq!(rows.len(), 3, "endpoint row + two NODE rows");
    let labels: Vec<String> = rows.iter().map(|r| r.label()).collect();
    assert!(labels.contains(&"trainer#node0".to_string()), "{labels:?}");
    assert!(labels.contains(&"trainer#node1".to_string()), "{labels:?}");
    let per_row: u64 = rows
        .iter()
        .filter_map(|r| r.report.hist("node.commit_us"))
        .map(|h| h.count())
        .sum();
    assert_eq!(per_row, 4, "two samples per worker, none elsewhere");
    let merged = c.merged_hist("node.commit_us").unwrap();
    assert_eq!(merged.count(), per_row, "fleet-merged count == sum of per-process counts");
    assert_eq!(c.summed_counter("node.updates"), 3 + 4);
}

#[test]
fn health_endpoint_down_fires_over_real_sockets() {
    // `amtl health` semantics end to end: one live trainer answering
    // FetchMetrics, one address nothing listens on. Exactly the
    // endpoint_down rule fires, attributed to the dead address.
    let p = lowrank_problem(9101, 2, 30, 5, 0.25);
    let cfg = RunConfig { iters_per_node: 2, record_every: 1_000_000, ..Default::default() };
    let (_state, server, recorder) = cfg.build_server(&p).unwrap();
    let mut handle =
        TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), Some(recorder)).unwrap();
    let live = handle.addr().to_string();
    // Find a loopback port with no listener: bind an ephemeral one and
    // drop it before polling.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    let mut c = Collector::new(&[live, dead.clone()]);
    let up = c.poll_with(0, |a| {
        let mut pc = PredictClient::connect(a, Duration::from_millis(500)).ok()?;
        let r = pc.metrics().ok();
        let _ = pc.close();
        r
    });
    assert_eq!(up, 1, "only the live trainer answers");
    let violations = HealthRules::default().evaluate(&c);
    assert_eq!(violations.len(), 1, "{violations:?}");
    assert_eq!(violations[0].rule, "endpoint_down");
    assert_eq!(violations[0].endpoint, dead);
    assert!(violations[0].to_string().contains("endpoint_down"), "{}", violations[0]);
    handle.shutdown();
}
