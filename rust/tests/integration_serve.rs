//! Integration tests for the serving tier: a replica fed only by the
//! trainer's checkpoint directory answers predictions that are bitwise
//! the trainer's own, never errors under concurrent predict traffic
//! while TCP training is live, and survives keep-2 checkpoint rotation
//! pruning the WAL segment it was parked on.

use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{MtlProblem, RunConfig, Session};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::persist::{recover, PersistConfig};
use amtl::runtime::Engine;
use amtl::serve::{ModelReplica, PredictClient, ReplicaCore, ReplicaServer};
use amtl::transport::{TcpClient, TcpOptions, TcpServer};
use amtl::util::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(5);

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("amtl_iserve_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

// ------------------------------------------- quiesce + drain ⇒ bitwise

#[test]
fn replica_predictions_match_the_trainer_bitwise_after_drain() {
    // Train to completion with checkpoints, then serve the directory:
    // once the replica drains the WAL, every prediction that crosses the
    // wire must equal ⟨w_t, x⟩ against the trainer's own final W — not
    // approximately, bitwise (same replay machinery, same fold order).
    let dir = tmp_dir("bitwise_predict");
    let p = lowrank_problem(6300, 2, 50, 8, 0.25);
    let r = Session::builder(&p)
        .iters_per_node(25)
        .eta_k(0.9)
        .record_every(1_000_000)
        .checkpoint_dir(Some(dir.clone()))
        .checkpoint_every(9)
        .build()
        .unwrap()
        .run()
        .unwrap();

    let mut replica = ModelReplica::follow(&dir, Duration::from_millis(5));
    let mut rep = ReplicaServer::spawn("127.0.0.1:0", &replica).unwrap();
    assert!(replica.wait_ready(Duration::from_secs(30)), "snapshot exists, bootstrap must land");

    // Wait for the drain by watching the model itself (lag can read 0
    // transiently right after bootstrap, before the first WAL discovery).
    let want = r.w_final.clone();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if replica.serving().map(|m| m.w == want).unwrap_or(false) {
            break;
        }
        assert!(Instant::now() < deadline, "replica never drained to the trainer's final W");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(replica.stats().lag(), 0, "drained replica admits no lag");

    let mut client = PredictClient::connect(rep.addr(), TIMEOUT).unwrap();
    let mut rng = Rng::new(99);
    let mut asked = 0u64;
    for t in 0..p.t() {
        for _ in 0..5 {
            let x = rng.normal_vec(p.d());
            let (y, model_seq) = client.predict(t, &x).unwrap();
            assert_eq!(y, amtl::linalg::dot(want.col(t), &x), "bitwise prediction, task {t}");
            assert!(model_seq > 0, "a drained model carries its WAL horizon");
            asked += 1;
        }
    }
    // Malformed requests get a clean refusal — and the connection (plus
    // the good path) keeps working afterwards.
    assert!(client.predict(p.t(), &rng.normal_vec(p.d())).is_err(), "task out of range");
    assert!(client.predict(0, &rng.normal_vec(p.d() + 1)).is_err(), "dimension mismatch");
    let x = rng.normal_vec(p.d());
    assert_eq!(client.predict(0, &x).unwrap().0, amtl::linalg::dot(want.col(0), &x));

    let s = client.stats().unwrap();
    assert_eq!(s.tasks as usize, p.t());
    assert_eq!(s.dim as usize, p.d());
    assert_eq!(s.errors, 2, "exactly the two malformed requests");
    assert!(s.predictions >= asked + 1);
    client.close().unwrap();
    rep.shutdown();
    replica.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------- live TCP training + concurrent predicts

#[test]
fn replica_never_errors_under_live_tcp_training() {
    // The acceptance bar of the tier: while a real multi-process-shaped
    // TCP training run commits updates (and checkpoint rotation prunes
    // WALs under the replica), concurrent predict clients must never see
    // an error or a non-finite score — every published model is a whole
    // batch, never a partially-applied column.
    let dir = tmp_dir("live_predict");
    let p = lowrank_problem(6301, 3, 60, 10, 0.3);
    let iters = 120;
    let cfg = RunConfig {
        iters_per_node: iters,
        record_every: 1_000_000,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 16,
        ..Default::default()
    };
    let (_state, server, recorder) = cfg.build_server(&p).unwrap();
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), Some(recorder)).unwrap();
    let addr = handle.addr();

    let mut replica = ModelReplica::follow(&dir, Duration::from_millis(5));
    let mut rep = ReplicaServer::spawn("127.0.0.1:0", &replica).unwrap();
    // build_server claimed the directory and cut genesis: the replica can
    // bootstrap before the first training commit.
    assert!(replica.wait_ready(Duration::from_secs(30)));
    let rep_addr = rep.addr();

    let mut computes = p.build_computes(Engine::Native, None).unwrap();
    let controller = Arc::new(StepController::new(KmSchedule::fixed(0.9), false, p.t(), 5));
    let mut root = Rng::new(6301);
    let done = Arc::new(AtomicBool::new(false));
    let t_count = p.t() as u64;
    let d = p.d();
    let (predictions, errors) = std::thread::scope(|s| {
        let mut workers = Vec::new();
        for (t, compute) in computes.iter_mut().enumerate() {
            let client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
            let ctx = WorkerCtx {
                t,
                iters,
                transport: Box::new(client),
                controller: Arc::clone(&controller),
                delay: DelayModel::None,
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng: root.fork(t as u64),
                gate: None,
                heartbeat: None,
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            workers.push(s.spawn(move || {
                run_worker(ctx, compute.as_mut()).expect("worker failed");
            }));
        }
        let mut predictors = Vec::new();
        for c in 0..3u64 {
            let done = Arc::clone(&done);
            predictors.push(s.spawn(move || -> (u64, u64) {
                let mut rng = Rng::new(900 + c);
                let mut client = PredictClient::connect(rep_addr, TIMEOUT).unwrap();
                let (mut ok, mut bad) = (0u64, 0u64);
                while !done.load(Ordering::SeqCst) {
                    let t = rng.below(t_count) as usize;
                    let x = rng.normal_vec(d);
                    match client.predict(t, &x) {
                        Ok((y, _)) if y.is_finite() => ok += 1,
                        _ => bad += 1,
                    }
                }
                let _ = client.close();
                (ok, bad)
            }));
        }
        for w in workers {
            w.join().unwrap();
        }
        done.store(true, Ordering::SeqCst);
        predictors
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |acc, x| (acc.0 + x.0, acc.1 + x.1))
    });
    assert!(predictions > 0, "the load window overlapped live training");
    assert_eq!(errors, 0, "no errors, no non-finite scores, ever");

    // Quiesce: final checkpoint, let the replica drain, compare models.
    server.sync_persist().unwrap();
    server.checkpointer().unwrap().checkpoint_now(&server).unwrap();
    let want = server.serving_w();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if replica.serving().map(|m| m.w == want).unwrap_or(false) {
            break;
        }
        assert!(Instant::now() < deadline, "replica never converged to the quiesced trainer");
        std::thread::sleep(Duration::from_millis(5));
    }
    handle.shutdown();
    rep.shutdown();
    replica.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------ rotation pruning ⇒ hot swap

#[test]
fn stranded_replica_hot_swaps_to_a_newer_snapshot() {
    // Park a replica at the horizon of a finished run, then resume the
    // run with aggressive rotation so keep-2 pruning deletes the WAL
    // segment the replica expects next. The replica must hot-swap onto a
    // newer snapshot and still land bitwise on the recovered final state.
    let dir = tmp_dir("hot_swap");
    let p = lowrank_problem(6302, 1, 40, 6, 0.2);
    let run = |iters: usize, resume: bool, every: u64| {
        Session::builder(&p)
            .iters_per_node(iters)
            .eta_k(0.9)
            .record_every(1_000_000)
            .checkpoint_dir(Some(dir.clone()))
            .checkpoint_every(every)
            .resume(resume)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    run(8, false, 1000);

    let mut core = ReplicaCore::bootstrap(&dir).unwrap();
    while core.poll().unwrap() > 0 {}
    assert_eq!(core.stats().hot_swaps, 0);
    let parked_at = core.expected_seq();

    let r = run(40, true, 4);

    let mut quiet = 0;
    let mut polls = 0;
    while quiet < 2 {
        if core.poll().unwrap() == 0 {
            quiet += 1;
        } else {
            quiet = 0;
        }
        polls += 1;
        assert!(polls < 10_000, "tail never drained");
    }
    assert!(
        core.stats().hot_swaps >= 1,
        "rotation pruned past seq {parked_at}; the replica must have swapped"
    );
    assert!(core.expected_seq() > parked_at);

    let rec = recover(PersistConfig::new(&dir, 4)).unwrap();
    let m = core.serving().unwrap();
    assert_eq!(m.w, rec.server.final_w(), "post-swap model recovers bitwise");
    assert_eq!(m.w, r.w_final, "…and equals the live run's final W");
    std::fs::remove_dir_all(&dir).ok();
}
