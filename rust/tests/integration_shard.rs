//! Integration tests for the sharded central server (`amtl::shard`):
//! the column-partitioned deployment must be *indistinguishable* from
//! the single whole-model server — bitwise for separable formulations,
//! within objective tolerance (via coordination rounds) for coupled
//! ones — and every shard must recover from its own checkpoint
//! directory, alone or as a group.

use amtl::coordinator::{Async, MtlProblem, Session};
use amtl::data::synthetic;
use amtl::linalg::Mat;
use amtl::optim::formulation::{self, FormulationSpec, FORMULATIONS};
use amtl::shard::{run_sharded, ProxShard, ShardMap, ShardRunConfig, SHARDMAP_FILE};
use amtl::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const D: usize = 6;
const T: usize = 5;
const LAMBDA: f64 = 0.3;

fn problem(reg: &str, seed: u64) -> MtlProblem {
    let spec = FormulationSpec::parse(reg).unwrap();
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&[20; T], D, 2, 0.1, &mut rng);
    MtlProblem::try_new(ds, spec, LAMBDA, 0.5, &mut rng).unwrap()
}

/// The single whole-model server this subsystem must reproduce: a plain
/// async `Session` run, same seed, same fixed KM step.
fn single_server(p: &MtlProblem, iters: usize, step: f64, seed: u64) -> (Mat, Mat, u64) {
    let r = Session::builder(p)
        .iters_per_node(iters)
        .eta_k(step)
        .seed(seed)
        .record_every(1_000_000)
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    (r.v_final, r.w_final, r.updates)
}

fn tmp(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("amtl_ishard_{}_{}", tag, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

// ------------------------------------------------- separable: bitwise

#[test]
fn sharded_runs_match_the_single_server_bitwise_on_every_separable_formulation() {
    // Registry-driven: every formulation that claims `is_separable()`
    // must shard with NO drift at all — the merged V and W of a 1-, 2-
    // and 3-shard run are bitwise the single-server result under the
    // same seed. A future formulation that sets the flag without the
    // column-decoupling property fails here, not in production.
    let mut covered = 0;
    for info in FORMULATIONS.iter() {
        let spec = FormulationSpec::parse(info.name).unwrap();
        let probe = formulation::resolve(&spec, LAMBDA, 1.0, T).unwrap();
        if !probe.is_separable() {
            continue;
        }
        covered += 1;
        let p = problem(info.name, 910);
        let (v_ref, w_ref, updates_ref) = single_server(&p, 20, 0.7, 41);
        assert_eq!(updates_ref, (T * 20) as u64);
        for shards in [1usize, 2, 3] {
            let res = run_sharded(&p, &ShardRunConfig::new(shards, 20, 0.7, 41)).unwrap();
            assert!(res.separable, "{} must shard separably", info.name);
            assert_eq!(res.rounds, 0, "{}: separable runs never coordinate", info.name);
            assert_eq!(res.updates, updates_ref, "{} @ {shards} shards", info.name);
            assert_eq!(
                res.merged_v.data(),
                v_ref.data(),
                "{}: merged V must be bitwise at {shards} shards",
                info.name
            );
            assert_eq!(
                res.merged_w.data(),
                w_ref.data(),
                "{}: merged W must be bitwise at {shards} shards",
                info.name
            );
        }
    }
    assert!(covered >= 3, "registry lost its separable family? covered {covered}");
}

// -------------------------------------- coupled: coordination rounds

#[test]
fn coordinated_formulations_converge_within_tolerance_via_rounds() {
    for name in ["nuclear", "graph"] {
        let p = problem(name, 911);
        let f_zero = p.objective(&Mat::zeros(D, T));
        let (_, w_ref, _) = single_server(&p, 80, 0.7, 43);
        let f_single = p.objective(&w_ref);

        let mut cfg = ShardRunConfig::new(2, 80, 0.7, 43);
        cfg.coord_every = 16;
        let res = run_sharded(&p, &cfg).unwrap();
        assert!(!res.separable, "{name} must take the coordination path");
        assert!(res.rounds >= 1, "{name}: coordination rounds must fire");
        let f_shard = res.objective;
        assert!(f_shard.is_finite() && f_single.is_finite());
        assert!(f_shard < f_zero, "{name}: sharded run failed to make progress");
        let rel = (f_shard - f_single).abs() / f_single.abs().max(1e-9);
        assert!(
            rel < 0.2,
            "{name}: sharded objective {f_shard} vs single-server {f_single} (rel {rel})"
        );
    }
}

// ------------------------------------------------ durability + resume

#[test]
fn interrupted_sharded_run_resumes_to_the_uninterrupted_model() {
    // Crash after 9 of 24 activations per node (drop without a final
    // checkpoint: recovery replays each shard's WAL), then `--resume`
    // the whole group. The spliced run must land bitwise on the model an
    // uninterrupted run produces.
    let p = problem("l1", 912);
    let full = run_sharded(&p, &ShardRunConfig::new(2, 24, 0.7, 44)).unwrap();

    let dir = tmp("resume");
    let mut phase1 = ShardRunConfig::new(2, 9, 0.7, 44);
    phase1.persist = Some((dir.clone(), 4));
    run_sharded(&p, &phase1).unwrap();

    let mut phase2 = ShardRunConfig::new(2, 24, 0.7, 44);
    phase2.persist = Some((dir.clone(), 4));
    phase2.resume = true;
    let resumed = run_sharded(&p, &phase2).unwrap();
    assert_eq!(resumed.merged_v.data(), full.merged_v.data(), "resumed V must be bitwise");
    assert_eq!(resumed.merged_w.data(), full.merged_w.data(), "resumed W must be bitwise");
    // Workers skip the activations their shard already applied.
    assert_eq!(resumed.updates, ((24 - 9) * T) as u64);
    // On-disk layout: one routing file, one directory per shard.
    assert!(dir.join(SHARDMAP_FILE).exists(), "SHARDMAP routing file");
    assert!(ShardMap::shard_dir(&dir, 0).is_dir());
    assert!(ShardMap::shard_dir(&dir, 1).is_dir());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn a_single_shard_recovers_alone_from_its_own_directory() {
    // The per-shard directory layout is what makes `--resume`-ing ONE
    // killed shard possible while its peers keep running: bring back
    // only shard 1 and its slice must be bitwise the columns the full
    // run left behind.
    let p = problem("elasticnet", 913);
    let dir = tmp("solo");
    let mut cfg = ShardRunConfig::new(2, 12, 0.7, 45);
    cfg.persist = Some((dir.clone(), 4));
    let res = run_sharded(&p, &cfg).unwrap();

    let map = Arc::new(ShardMap::load(&dir).unwrap());
    assert_eq!(map.shards(), 2);
    let proto = p.regularizer();
    let shard = ProxShard::resume(Arc::clone(&map), 1, proto.as_ref(), p.eta, &dir, 4).unwrap();
    let slice = shard.server().state().snapshot();
    for (local, global) in shard.range().enumerate() {
        assert_eq!(
            slice.col(local),
            res.merged_v.col(global),
            "recovered column {global} diverged"
        );
    }
    for t in shard.range() {
        assert_eq!(shard.applied_commits(t).unwrap(), 12, "resume horizon for task {t}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinated_shards_survive_checkpoint_and_resume() {
    // The non-separable path persists an honest identity regularizer per
    // shard; a resumed group reseeds its coordination caches from the
    // recovered slices and keeps improving the objective.
    let p = problem("nuclear", 914);
    let dir = tmp("coord");
    let mut phase1 = ShardRunConfig::new(2, 10, 0.7, 46);
    phase1.coord_every = 8;
    phase1.persist = Some((dir.clone(), 4));
    let first = run_sharded(&p, &phase1).unwrap();
    assert!(first.rounds >= 1);
    assert!(first.objective.is_finite());

    let mut phase2 = phase1.clone();
    phase2.iters = 30;
    phase2.resume = true;
    let resumed = run_sharded(&p, &phase2).unwrap();
    assert!(!resumed.separable);
    assert!(resumed.rounds >= 1, "a resumed group must keep coordinating");
    assert!(resumed.objective.is_finite());
    assert!(
        resumed.objective <= first.objective * 1.10 + 1e-6,
        "20 extra activations per node must not hurt: {} vs {}",
        resumed.objective,
        first.objective
    );
    std::fs::remove_dir_all(&dir).ok();
}
