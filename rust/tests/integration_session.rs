//! Integration tests for the unified `Session` + `Schedule` API: builder
//! validation, convergence against the centralized FISTA reference, and
//! the semi-synchronous schedule the old forked drivers could not express.

use amtl::coordinator::{
    Async, MtlProblem, RunConfig, Schedule, SemiSync, Session, Synchronized,
};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::Engine;
use amtl::util::Rng;
use std::time::Duration;

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

// ----------------------------------------------------------- validation

#[test]
fn builder_reports_compute_count_mismatch() {
    let p = lowrank_problem(800, 4, 10, 4, 0.1);
    let mut computes = p.build_computes(Engine::Native, None).unwrap();
    computes.pop();
    let err = Session::builder(&p).computes(computes).build().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("one compute per task"), "{msg}");
}

#[test]
fn builder_reports_bad_schedule_params() {
    let p = lowrank_problem(801, 2, 10, 4, 0.1);
    let err = Session::builder(&p)
        .schedule(SemiSync { staleness_bound: 0 })
        .build()
        .unwrap_err();
    assert!(format!("{err}").contains("staleness_bound"), "{err}");
}

#[test]
fn builder_reports_bad_run_config() {
    let p = lowrank_problem(802, 2, 10, 4, 0.1);
    assert!(Session::builder(&p).sgd_fraction(Some(2.0)).build().is_err());
    assert!(Session::builder(&p).eta_k(-0.5).build().is_err());
    assert!(Session::builder(&p).dyn_window(0).build().is_err());
}

// ------------------------------------------------- determinism & quality

#[test]
fn session_async_is_deterministic_on_one_task() {
    // One task ⇒ no thread interleaving ⇒ two runs must agree exactly.
    let p = lowrank_problem(803, 1, 40, 6, 0.2);
    let cfg = RunConfig { iters_per_node: 30, ..Default::default() };
    let run = || {
        Session::builder(&p)
            .config(cfg.clone())
            .schedule(Async)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.v_final, r2.v_final, "V bit-identical");
    assert_eq!(r1.w_final, r2.w_final, "W bit-identical");
    assert_eq!(r1.updates, r2.updates);
    assert_eq!(r1.prox_count, r2.prox_count);
    assert_eq!(r1.method, "amtl");
}

#[test]
fn session_async_converges_to_fista_optimum() {
    let p = lowrank_problem(804, 4, 50, 6, 0.2);
    // Centralized FISTA reference optimum.
    let tasks = p.fista_tasks();
    let mut reg = p.regularizer();
    let fista = amtl::optim::fista::fista(&tasks, &mut reg, p.l_max, 2000, 1e-12);
    let f_star = *fista.history.last().unwrap();

    let r = Session::builder(&p)
        .iters_per_node(400)
        .eta_k(0.9)
        .record_every(1_000_000)
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let f_amtl = p.objective(&r.w_final);
    assert!(
        f_amtl <= f_star * 1.05 + 1e-6,
        "AMTL {f_amtl} vs FISTA {f_star}"
    );
}

#[test]
fn online_svd_matches_exact_across_all_schedules() {
    // Brand's incremental SVD must track the exact Jacobi prox under
    // every update schedule, not just decrease the objective on its own.
    use amtl::optim::svd::SvdMode;
    let p = lowrank_problem(810, 3, 30, 6, 0.2);
    let run = |mode: SvdMode, schedule: Box<dyn amtl::coordinator::Schedule>| {
        Session::builder(&p)
            .iters_per_node(30)
            .svd(mode)
            .resvd_every(8)
            .schedule_box(schedule)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let make = |name: &str| -> Box<dyn amtl::coordinator::Schedule> {
        match name {
            "amtl" => Box::new(Async),
            "smtl" => Box::new(Synchronized),
            _ => Box::new(SemiSync { staleness_bound: 2 }),
        }
    };
    for name in ["amtl", "smtl", "semisync"] {
        let exact = run(SvdMode::Exact, make(name));
        let online = run(SvdMode::Online, make(name));
        let f_exact = p.objective(&exact.w_final);
        let f_online = p.objective(&online.w_final);
        assert_eq!(exact.svd_refreshes, 0, "{name}: exact path never refreshes");
        assert!(
            (f_exact - f_online).abs() / f_exact.max(1e-9) < 0.2,
            "{name}: exact {f_exact} vs online {f_online}"
        );
    }
}

#[test]
fn synchronized_online_svd_is_deterministically_close_to_exact() {
    // SMTL commits in a fixed task order with no free-running threads, so
    // the online-vs-exact comparison is deterministic: the two runs see
    // identical update sequences and the final objectives must agree to
    // numerical (not stochastic) tolerance.
    use amtl::optim::svd::SvdMode;
    let p = lowrank_problem(811, 4, 25, 5, 0.2);
    let run = |mode: SvdMode| {
        Session::builder(&p)
            .iters_per_node(25)
            .svd(mode)
            .resvd_every(8)
            .schedule(amtl::coordinator::Synchronized)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let f_exact = p.objective(&run(SvdMode::Exact).w_final);
    let f_online = p.objective(&run(SvdMode::Online).w_final);
    assert!(
        (f_exact - f_online).abs() <= 1e-6 * f_exact.abs().max(1.0),
        "exact {f_exact} vs online {f_online}"
    );
}

#[test]
fn session_records_decreasing_trajectory() {
    let p = lowrank_problem(809, 3, 20, 4, 0.1);
    let r = Session::builder(&p)
        .iters_per_node(10)
        .record_every(5)
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    // 30 updates / stride 5 = ~6 samples + initial + final.
    assert!(r.trajectory.len() >= 4, "only {} points", r.trajectory.len());
    let objs = r.compute_objectives(|w| p.objective(w), |v| p.prox_map(v));
    assert!(objs.last().unwrap().2 < objs[0].2, "objective must decrease");
}

// --------------------------------------------------------- semi-sync

#[test]
fn semisync_converges_like_the_extremes() {
    let p = lowrank_problem(805, 5, 50, 8, 0.3);
    let run = |schedule: Box<dyn Schedule>| {
        Session::builder(&p)
            .iters_per_node(200)
            .eta_k(0.9)
            .record_every(1_000_000)
            .schedule_box(schedule)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let f_async = p.objective(&run(Box::new(Async)).w_final);
    let f_semi = p.objective(&run(Box::new(SemiSync { staleness_bound: 4 })).w_final);
    let f_sync = p.objective(&run(Box::new(Synchronized)).w_final);
    assert!(
        (f_semi - f_sync).abs() / f_sync.max(1e-9) < 0.05,
        "semisync {f_semi} vs sync {f_sync}"
    );
    assert!(
        (f_semi - f_async).abs() / f_async.max(1e-9) < 0.05,
        "semisync {f_semi} vs async {f_async}"
    );
}

#[test]
fn semisync_full_budget_under_heterogeneous_delays() {
    // A straggler cannot be left behind by more than the bound, and every
    // node still finishes its budget.
    let p = lowrank_problem(806, 4, 20, 5, 0.2);
    let fast = DelayModel::OffsetJitter {
        offset: Duration::from_millis(1),
        jitter: Duration::ZERO,
    };
    let slow = DelayModel::OffsetJitter {
        offset: Duration::from_millis(8),
        jitter: Duration::ZERO,
    };
    let r = Session::builder(&p)
        .iters_per_node(12)
        .delay(DelayModel::PerNode {
            per_node: vec![
                Box::new(slow),
                Box::new(fast.clone()),
                Box::new(fast.clone()),
                Box::new(fast),
            ],
        })
        .schedule(SemiSync { staleness_bound: 2 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.updates_per_node, vec![12; 4]);
    assert_eq!(r.method, "semisync");
    // The bound makes fast nodes pace the straggler: total wall is at
    // least the straggler's own serial budget.
    assert!(r.wall_time >= Duration::from_millis(8 * 12 - 20), "wall {:?}", r.wall_time);
}

#[test]
fn semisync_tolerates_crash_and_drop_faults() {
    let p = lowrank_problem(807, 4, 30, 6, 0.2);
    let r = Session::builder(&p)
        .iters_per_node(40)
        .faults(FaultModel::Compose(vec![
            FaultModel::CrashAfter { node: 3, after: 10 },
            FaultModel::DropActivation { p: 0.2 },
        ]))
        .schedule(SemiSync { staleness_bound: 3 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.crashed_nodes, vec![3]);
    assert!(r.dropped_updates > 0, "expected some dropped updates");
    assert!(r.updates + r.dropped_updates <= 160);
    assert!(p.objective(&r.w_final).is_finite());
}

// ------------------------------------------------- builder conveniences

#[test]
fn paper_offset_injects_delays() {
    let p = lowrank_problem(808, 3, 10, 4, 0.1);
    let r = Session::builder(&p)
        .iters_per_node(3)
        .time_scale(Duration::from_millis(2))
        .paper_offset(1.0)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        r.mean_delay_secs > 0.0,
        "paper offset must produce nonzero delays"
    );
}
