//! Integration tests for the unified `Session` + `Schedule` API: builder
//! validation, equivalence with the deprecated entry points, and the
//! semi-synchronous schedule the old forked drivers could not express.

use amtl::coordinator::{
    Async, MtlProblem, RunConfig, Schedule, SemiSync, Session, Synchronized,
};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::runtime::Engine;
use amtl::util::Rng;
use std::time::Duration;

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

// ----------------------------------------------------------- validation

#[test]
fn builder_reports_compute_count_mismatch() {
    let p = lowrank_problem(800, 4, 10, 4, 0.1);
    let mut computes = p.build_computes(Engine::Native, None).unwrap();
    computes.pop();
    let err = Session::builder(&p).computes(computes).build().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("one compute per task"), "{msg}");
}

#[test]
fn builder_reports_bad_schedule_params() {
    let p = lowrank_problem(801, 2, 10, 4, 0.1);
    let err = Session::builder(&p)
        .schedule(SemiSync { staleness_bound: 0 })
        .build()
        .unwrap_err();
    assert!(format!("{err}").contains("staleness_bound"), "{err}");
}

#[test]
fn builder_reports_bad_run_config() {
    let p = lowrank_problem(802, 2, 10, 4, 0.1);
    assert!(Session::builder(&p).sgd_fraction(Some(2.0)).build().is_err());
    assert!(Session::builder(&p).eta_k(-0.5).build().is_err());
    assert!(Session::builder(&p).dyn_window(0).build().is_err());
}

// ------------------------------------------------- shim equivalence

#[test]
#[allow(deprecated)]
fn session_async_is_bit_identical_to_run_amtl_on_one_task() {
    // One task ⇒ no thread interleaving ⇒ both paths must agree exactly.
    let p = lowrank_problem(803, 1, 40, 6, 0.2);
    let cfg = RunConfig { iters_per_node: 30, ..Default::default() };
    let r_new = Session::builder(&p)
        .config(cfg.clone())
        .schedule(Async)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let r_old = amtl::coordinator::run_amtl(
        &p,
        p.build_computes(Engine::Native, None).unwrap(),
        &cfg,
    )
    .unwrap();
    assert_eq!(r_new.v_final, r_old.v_final, "V bit-identical");
    assert_eq!(r_new.w_final, r_old.w_final, "W bit-identical");
    assert_eq!(r_new.updates, r_old.updates);
    assert_eq!(r_new.prox_count, r_old.prox_count);
    assert_eq!(r_new.method, r_old.method);
}

#[test]
#[allow(deprecated)]
fn session_synchronized_matches_run_smtl_updates_and_objective() {
    let p = lowrank_problem(804, 4, 30, 6, 0.2);
    let r_new = Session::builder(&p)
        .iters_per_node(25)
        .eta_k(0.9)
        .schedule(Synchronized)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let old_cfg = amtl::coordinator::SmtlConfig {
        iters: 25,
        km: amtl::coordinator::step_size::KmSchedule::fixed(0.9),
        ..Default::default()
    };
    let r_old = amtl::coordinator::run_smtl(
        &p,
        p.build_computes(Engine::Native, None).unwrap(),
        &old_cfg,
    )
    .unwrap();
    assert_eq!(r_new.updates, r_old.updates);
    assert_eq!(r_new.updates_per_node, r_old.updates_per_node);
    let f_new = p.objective(&r_new.w_final);
    let f_old = p.objective(&r_old.w_final);
    // Synchronized rounds are deterministic in value: exact agreement.
    assert!(
        (f_new - f_old).abs() < 1e-9,
        "sync objective {f_new} vs shim {f_old}"
    );
}

// --------------------------------------------------------- semi-sync

#[test]
fn semisync_converges_like_the_extremes() {
    let p = lowrank_problem(805, 5, 50, 8, 0.3);
    let run = |schedule: Box<dyn Schedule>| {
        Session::builder(&p)
            .iters_per_node(200)
            .eta_k(0.9)
            .record_every(1_000_000)
            .schedule_box(schedule)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let f_async = p.objective(&run(Box::new(Async)).w_final);
    let f_semi = p.objective(&run(Box::new(SemiSync { staleness_bound: 4 })).w_final);
    let f_sync = p.objective(&run(Box::new(Synchronized)).w_final);
    assert!(
        (f_semi - f_sync).abs() / f_sync.max(1e-9) < 0.05,
        "semisync {f_semi} vs sync {f_sync}"
    );
    assert!(
        (f_semi - f_async).abs() / f_async.max(1e-9) < 0.05,
        "semisync {f_semi} vs async {f_async}"
    );
}

#[test]
fn semisync_full_budget_under_heterogeneous_delays() {
    // A straggler cannot be left behind by more than the bound, and every
    // node still finishes its budget.
    let p = lowrank_problem(806, 4, 20, 5, 0.2);
    let fast = DelayModel::OffsetJitter {
        offset: Duration::from_millis(1),
        jitter: Duration::ZERO,
    };
    let slow = DelayModel::OffsetJitter {
        offset: Duration::from_millis(8),
        jitter: Duration::ZERO,
    };
    let r = Session::builder(&p)
        .iters_per_node(12)
        .delay(DelayModel::PerNode {
            per_node: vec![
                Box::new(slow),
                Box::new(fast.clone()),
                Box::new(fast.clone()),
                Box::new(fast),
            ],
        })
        .schedule(SemiSync { staleness_bound: 2 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.updates_per_node, vec![12; 4]);
    assert_eq!(r.method, "semisync");
    // The bound makes fast nodes pace the straggler: total wall is at
    // least the straggler's own serial budget.
    assert!(r.wall_time >= Duration::from_millis(8 * 12 - 20), "wall {:?}", r.wall_time);
}

#[test]
fn semisync_tolerates_crash_and_drop_faults() {
    let p = lowrank_problem(807, 4, 30, 6, 0.2);
    let r = Session::builder(&p)
        .iters_per_node(40)
        .faults(FaultModel::Both {
            drop_p: 0.2,
            crash_node: 3,
            crash_after: 10,
        })
        .schedule(SemiSync { staleness_bound: 3 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(r.crashed_nodes, vec![3]);
    assert!(r.dropped_updates > 0, "expected some dropped updates");
    assert!(r.updates + r.dropped_updates <= 160);
    assert!(p.objective(&r.w_final).is_finite());
}

// ------------------------------------------------- builder conveniences

#[test]
fn paper_offset_injects_delays() {
    let p = lowrank_problem(808, 3, 10, 4, 0.1);
    let r = Session::builder(&p)
        .iters_per_node(3)
        .time_scale(Duration::from_millis(2))
        .paper_offset(1.0)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        r.mean_delay_secs > 0.0,
        "paper offset must produce nonzero delays"
    );
}
