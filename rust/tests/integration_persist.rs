//! Integration tests for durability + elastic membership: checkpointed
//! sessions recover bitwise, a SIGKILL'd serving process resumes to the
//! same answer, and a task node that dies mid-training is evicted and
//! replaced without losing the run.

use amtl::coordinator::registry::NodeRegistry;
use amtl::coordinator::server::CentralServer;
use amtl::coordinator::state::SharedState;
use amtl::coordinator::step_size::{KmSchedule, StepController};
use amtl::coordinator::worker::{run_worker, WorkerCtx};
use amtl::coordinator::{MtlProblem, SemiSync, Session, Synchronized};
use amtl::data::synthetic;
use amtl::net::{DelayModel, FaultModel};
use amtl::optim::prox::RegularizerKind;
use amtl::persist::{has_checkpoint, recover, PersistConfig};
use amtl::runtime::TaskCompute;
use amtl::transport::{TcpClient, TcpOptions, TcpServer, Transport};
use amtl::util::Rng;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("amtl_ipersist_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn lowrank_problem(seed: u64, t: usize, n: usize, d: usize, lambda: f64) -> MtlProblem {
    let mut rng = Rng::new(seed);
    let ds = synthetic::lowrank_regression(&vec![n; t], d, 2, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, lambda, 0.5, &mut rng)
}

// ------------------------------------------------ in-proc bitwise recovery

#[test]
fn checkpointed_session_recovers_bitwise() {
    // One task ⇒ a strictly sequential commit/prox history ⇒ snapshot +
    // WAL replay must reproduce the server — online-SVD factorization
    // included — bit for bit.
    let dir = tmp_dir("session_bitwise");
    let p = lowrank_problem(840, 1, 40, 6, 0.2);
    let r = Session::builder(&p)
        .iters_per_node(30)
        .eta_k(0.9)
        .record_every(1_000_000)
        .checkpoint_dir(Some(dir.clone()))
        .checkpoint_every(7)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(r.checkpoints_written >= 2, "genesis + at least one rotation");
    assert!(has_checkpoint(&dir));

    let rec = recover(PersistConfig::new(&dir, 7)).unwrap();
    assert_eq!(rec.server.state().snapshot(), r.v_final, "V recovers bitwise");
    assert_eq!(rec.server.final_w(), r.w_final, "W recovers bitwise");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resumed_session_continues_to_the_uninterrupted_answer() {
    // Run 8 of 20 activations, drop everything, resume from disk for the
    // remaining 12: the final iterate must equal a straight 20-activation
    // run bitwise (single task ⇒ deterministic; commit dedup keys make
    // the resumed worker start exactly where the durable state ends).
    let dir = tmp_dir("session_resume");
    let p = lowrank_problem(841, 1, 40, 6, 0.2);
    let run = |iters: usize, resume: bool, checkpoint: bool| {
        let mut b = Session::builder(&p)
            .iters_per_node(iters)
            .eta_k(0.9)
            .record_every(1_000_000);
        if checkpoint {
            b = b.checkpoint_dir(Some(dir.clone())).checkpoint_every(5).resume(resume);
        }
        b.build().unwrap().run().unwrap()
    };
    let partial = run(8, false, true);
    assert_eq!(partial.updates, 8);

    let resumed = run(20, true, true);
    assert_eq!(resumed.updates, 12, "resume skips the 8 applied activations");
    assert!(resumed.wal_replayed > 0, "the WAL tail must have replayed");

    let uninterrupted = run(20, false, false);
    assert_eq!(resumed.w_final, uninterrupted.w_final, "resumed W bitwise");
    assert_eq!(resumed.v_final, uninterrupted.v_final, "resumed V bitwise");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resumed_synchronized_session_continues_at_the_right_round() {
    // The round counter must continue at the durable horizon: restarting
    // it at 0 would let the dedup keys silently swallow the resumed
    // rounds (regression test).
    let dir = tmp_dir("resume_smtl");
    let p = lowrank_problem(843, 2, 30, 5, 0.2);
    let run = |iters: usize, resume: bool, checkpoint: bool| {
        let mut b = Session::builder(&p)
            .iters_per_node(iters)
            .eta_k(0.9)
            .record_every(1_000_000)
            .schedule(Synchronized);
        if checkpoint {
            b = b.checkpoint_dir(Some(dir.clone())).checkpoint_every(4).resume(resume);
        }
        b.build().unwrap().run().unwrap()
    };
    let partial = run(6, false, true);
    assert_eq!(partial.updates, 12, "6 rounds x 2 nodes");
    let resumed = run(15, true, true);
    assert_eq!(resumed.updates, 18, "9 resumed rounds x 2 nodes");
    let uninterrupted = run(15, false, false);
    assert_eq!(resumed.v_final, uninterrupted.v_final, "smtl resume is bitwise");
    assert_eq!(resumed.w_final, uninterrupted.w_final);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resumed_semisync_session_does_not_stall() {
    // The staleness gate's completed counters are primed with the
    // applied-commit horizons on resume: with them at 0, every resumed
    // worker would park forever (regression test).
    let dir = tmp_dir("resume_semisync");
    let p = lowrank_problem(844, 3, 20, 5, 0.2);
    let run = |iters: usize, resume: bool| {
        Session::builder(&p)
            .iters_per_node(iters)
            .eta_k(0.9)
            .record_every(1_000_000)
            .checkpoint_dir(Some(dir.clone()))
            .checkpoint_every(5)
            .resume(resume)
            .schedule(SemiSync { staleness_bound: 2 })
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let partial = run(5, false);
    assert_eq!(partial.updates, 15);
    let resumed = run(12, true);
    assert_eq!(resumed.updates, 21, "7 resumed activations x 3 nodes");
    assert_eq!(resumed.updates_per_node, vec![7; 3]);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------- SIGKILL the serve process

fn amtl_bin() -> &'static str {
    env!("CARGO_BIN_EXE_amtl")
}

/// The shared problem definition every process derives (mirrors
/// `build_problem` for `--tasks 1 --n 40 --dim 6` + defaults).
fn serve_problem() -> MtlProblem {
    let mut rng = Rng::new(7);
    let ds = synthetic::lowrank_regression(&[40; 1], 6, 3, 0.1, &mut rng);
    MtlProblem::new(ds, RegularizerKind::Nuclear, 0.5, 0.5, &mut rng)
}

/// Spawn `amtl --serve 127.0.0.1:0 …` and return the child plus the
/// address it reports on stdout (the rest of stdout keeps draining in a
/// background thread so the child never blocks on a full pipe).
fn spawn_serve(dir: &Path, resume: bool) -> (Child, String) {
    let mut cmd = Command::new(amtl_bin());
    cmd.args([
        "--serve",
        "127.0.0.1:0",
        "--tasks",
        "1",
        "--n",
        "40",
        "--dim",
        "6",
        "--iters",
        "60",
        "--svd",
        "exact",
        "--checkpoint-every",
        "8",
        "--checkpoint-dir",
    ])
    .arg(dir)
    .stdout(Stdio::piped())
    .stderr(Stdio::null());
    if resume {
        cmd.arg("--resume");
    }
    let mut child = cmd.spawn().expect("spawn amtl --serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        for line in std::io::BufReader::new(stdout).lines().map_while(Result::ok) {
            if let Some(addr) = line.strip_prefix("central node serving on ") {
                let _ = tx.send(addr.trim().to_string());
            }
        }
    });
    let addr = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve process must report its address");
    (child, addr)
}

fn serve_worker(addr: &str, resume: bool, delay: DelayModel, opts: TcpOptions) -> WorkerCtx {
    let client = TcpClient::connect(addr, opts).expect("connect to serve process");
    WorkerCtx {
        t: 0,
        iters: 60,
        transport: Box::new(client),
        controller: Arc::new(StepController::new(KmSchedule::fixed(0.5), false, 1, 5)),
        delay,
        faults: FaultModel::None,
        sgd_fraction: None,
        time_scale: Duration::from_millis(100),
        sink: None,
        rng: Rng::new(7).fork(0),
        gate: None,
        heartbeat: None,
        resume,
        trace: None,
        metrics_stride: None,
    }
}

fn wait_exit(child: &mut Child, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => {
                assert!(status.success(), "{what} exited with {status}");
                return;
            }
            None if Instant::now() > deadline => {
                let _ = child.kill();
                panic!("{what} did not exit in time");
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

/// Objective of the state a checkpoint directory recovers to.
fn recovered_objective(dir: &Path, p: &MtlProblem) -> f64 {
    let rec = recover(PersistConfig::new(dir, 8)).unwrap();
    assert_eq!(rec.server.state().col_version(0), 60, "full budget recovered");
    p.objective(&rec.server.final_w())
}

#[test]
fn sigkilled_server_resumes_to_the_uninterrupted_objective() {
    let p = serve_problem();

    // Reference: uninterrupted serve + node run.
    let dir_a = tmp_dir("serve_ref");
    let (mut child_a, addr_a) = spawn_serve(&dir_a, false);
    let mut compute_a = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let stats = run_worker(
        serve_worker(&addr_a, false, DelayModel::None, TcpOptions::default()),
        compute_a[0].as_mut(),
    )
    .unwrap();
    assert_eq!(stats.updates, 60);
    wait_exit(&mut child_a, "uninterrupted serve");
    let f_ref = recovered_objective(&dir_a, &p);

    // Interrupted: same run, but the server is SIGKILL'd mid-training.
    let dir_b = tmp_dir("serve_kill");
    let (mut child_b, addr_b) = spawn_serve(&dir_b, false);
    let mut compute_b = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    // ~25 ms per activation: the 60-activation budget takes ~1.5 s, and
    // the kill lands mid-run. Short retries so the orphaned worker gives
    // up quickly once the server is gone.
    let slow = DelayModel::OffsetJitter { offset: Duration::from_millis(25), jitter: Duration::ZERO };
    let quick = TcpOptions {
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_millis(500),
        retries: 2,
        retry_backoff: Duration::from_millis(50),
    };
    let worker = std::thread::spawn({
        let addr_b = addr_b.clone();
        let mut compute = compute_b.remove(0);
        move || {
            // The worker errors out when the server dies under it —
            // that is the expected outcome, not a test failure.
            let _ = run_worker(serve_worker(&addr_b, false, slow, quick), compute.as_mut());
        }
    });
    std::thread::sleep(Duration::from_millis(700));
    child_b.kill().expect("SIGKILL the serve process");
    let _ = child_b.wait();
    worker.join().unwrap();

    // Some progress must have been made and must have survived the kill.
    let partial = recover(PersistConfig::new(&dir_b, 8)).unwrap();
    let done = partial.server.state().col_version(0);
    assert!(done > 0 && done < 60, "kill must land mid-run (got {done} commits)");
    drop(partial);

    // Restart with --resume; a fresh node catches up from the applied-
    // commit horizon and finishes the budget.
    let (mut child_b2, addr_b2) = spawn_serve(&dir_b, true);
    let mut compute_b2 = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let stats = run_worker(
        serve_worker(&addr_b2, true, DelayModel::None, TcpOptions::default()),
        compute_b2[0].as_mut(),
    )
    .unwrap();
    assert_eq!(stats.updates + done, 60, "resumed node does only the remainder");
    wait_exit(&mut child_b2, "resumed serve");

    // Acceptance: the resumed run lands on the uninterrupted objective.
    let f_resumed = recovered_objective(&dir_b, &p);
    assert!(
        (f_resumed - f_ref).abs() < 1e-10,
        "objective after kill+resume {f_resumed} vs uninterrupted {f_ref}"
    );
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

#[test]
fn torn_wal_under_latency_storm_resumes_bitwise() {
    // Chaos variant of the SIGKILL test: the worker runs under a latency
    // storm (a straggler offset plus per-activation jitter), so the kill
    // lands at an unpredictable point of the commit/fsync interleaving —
    // very possibly mid-WAL-record. Recovery must tolerate the torn tail
    // and `--resume` must land BITWISE on the uninterrupted reference.
    // The storm is latency-only by construction: random drops would
    // desynchronize the fault RNG across the restart and lost activations
    // would starve the serve process of its 60-commit budget — delay
    // chaos perturbs timing and durability interleaving, never values.
    let p = serve_problem();
    let storm = DelayModel::OffsetJitter {
        offset: Duration::from_millis(15),
        jitter: Duration::from_millis(20),
    };

    // Reference: the same storm, uninterrupted.
    let dir_a = tmp_dir("torn_ref");
    let (mut child_a, addr_a) = spawn_serve(&dir_a, false);
    let mut compute_a = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let stats = run_worker(
        serve_worker(&addr_a, false, storm.clone(), TcpOptions::default()),
        compute_a[0].as_mut(),
    )
    .unwrap();
    assert_eq!(stats.updates, 60);
    wait_exit(&mut child_a, "uninterrupted storm serve");
    let rec_ref = recover(PersistConfig::new(&dir_a, 8)).unwrap();
    let w_ref = rec_ref.server.final_w();
    let v_ref = rec_ref.server.state().snapshot();
    drop(rec_ref);

    // Interrupted: same storm, SIGKILL mid-run.
    let dir_b = tmp_dir("torn_kill");
    let (mut child_b, addr_b) = spawn_serve(&dir_b, false);
    let mut compute_b = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let quick = TcpOptions {
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_millis(500),
        retries: 2,
        retry_backoff: Duration::from_millis(50),
    };
    let worker = std::thread::spawn({
        let addr_b = addr_b.clone();
        let storm = storm.clone();
        let mut compute = compute_b.remove(0);
        move || {
            // Expected to error out when the server dies under it.
            let _ = run_worker(serve_worker(&addr_b, false, storm, quick), compute.as_mut());
        }
    });
    std::thread::sleep(Duration::from_millis(600));
    child_b.kill().expect("SIGKILL the serve process mid-storm");
    let _ = child_b.wait();
    worker.join().unwrap();

    // The kill must land mid-run, and whatever the WAL tail looks like —
    // torn final record included — recovery must accept it.
    let partial = recover(PersistConfig::new(&dir_b, 8)).unwrap();
    let done = partial.server.state().col_version(0);
    assert!(done > 0 && done < 60, "kill must land mid-run (got {done} commits)");
    drop(partial);

    // Resume under the same storm; the node redoes only the remainder.
    let (mut child_b2, addr_b2) = spawn_serve(&dir_b, true);
    let mut compute_b2 = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let stats = run_worker(
        serve_worker(&addr_b2, true, storm, TcpOptions::default()),
        compute_b2[0].as_mut(),
    )
    .unwrap();
    assert_eq!(stats.updates + done, 60, "resumed node does only the remainder");
    wait_exit(&mut child_b2, "resumed storm serve");

    let rec = recover(PersistConfig::new(&dir_b, 8)).unwrap();
    assert_eq!(rec.server.final_w(), w_ref, "W lands bitwise on the reference");
    assert_eq!(rec.server.state().snapshot(), v_ref, "V lands bitwise on the reference");
    assert!(p.objective(&rec.server.final_w()).is_finite());
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

// ------------------------------------- kill and replace a TCP task node

#[test]
fn killed_tcp_node_is_evicted_and_a_replacement_catches_up() {
    let p = lowrank_problem(842, 3, 40, 6, 0.2);
    let iters = 100;

    // Reference objective: plain in-proc session, same seeds.
    let f_ref = {
        let r = Session::builder(&p)
            .iters_per_node(iters)
            .eta_k(0.9)
            .record_every(1_000_000)
            .build()
            .unwrap()
            .run()
            .unwrap();
        p.objective(&r.w_final)
    };

    // Cluster under test: TCP server + registry (20 ms heartbeats, 60 ms
    // eviction timeout).
    let state = Arc::new(SharedState::zeros(p.d(), p.t()));
    let registry = Arc::new(NodeRegistry::new(p.t(), Duration::from_millis(60)));
    let server = Arc::new(
        CentralServer::new(Arc::clone(&state), p.regularizer(), p.eta)
            .with_registry(Arc::clone(&registry)),
    );
    let mut handle = TcpServer::spawn("127.0.0.1:0", Arc::clone(&server), None).unwrap();
    let addr = handle.addr();

    let mut computes = p.build_computes(amtl::runtime::Engine::Native, None).unwrap();
    let controller = Arc::new(StepController::new(KmSchedule::fixed(0.9), false, p.t(), 5));
    let mut victim_compute = computes.remove(1); // task 1's private data
    let mut root = Rng::new(7);
    let rng0 = root.fork(0);
    let rng1 = root.fork(1);
    let rng2 = root.fork(2);

    std::thread::scope(|s| {
        // Peers 0 and 2: full budget, paced by a small per-activation
        // delay so they outlive the victim's death + replacement.
        let (left, right) = computes.split_at_mut(1);
        for (t, compute, rng) in [(0usize, &mut left[0], rng0), (2, &mut right[0], rng2)] {
            let controller = Arc::clone(&controller);
            let client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
            let ctx = WorkerCtx {
                t,
                iters,
                transport: Box::new(client),
                controller,
                delay: DelayModel::OffsetJitter {
                    offset: Duration::from_millis(8),
                    jitter: Duration::ZERO,
                },
                faults: FaultModel::None,
                sgd_fraction: None,
                time_scale: Duration::from_millis(100),
                sink: None,
                rng,
                gate: None,
                heartbeat: Some(Duration::from_millis(20)),
                resume: false,
                trace: None,
                metrics_stride: None,
            };
            let compute = &mut **compute;
            s.spawn(move || {
                let stats = run_worker(ctx, compute).unwrap();
                assert_eq!(stats.updates, iters as u64);
            });
        }

        // The victim: drive 30 activations of task 1 by hand, then DROP
        // the connection — a silent death, no Leave frame, mid-training.
        let mut client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
        client.register(1).unwrap();
        let eta = client.eta();
        for k in 0..30u64 {
            let w_hat = client.fetch_prox_col(1).unwrap();
            let (u, _loss) = victim_compute.step(&w_hat, eta).unwrap();
            client.push_update(1, k, 0.9, &u).unwrap();
        }
        drop(client);

        // The peers' heartbeats sweep the registry: the silent node is
        // evicted within the timeout.
        let deadline = Instant::now() + Duration::from_secs(10);
        while !registry.is_evicted(1) {
            assert!(Instant::now() < deadline, "victim was never evicted");
            std::thread::sleep(Duration::from_millis(10));
        }

        // The replacement node registers, learns 30 commits are already
        // applied, and does exactly the remaining 70.
        let client = TcpClient::connect(addr, TcpOptions::default()).unwrap();
        let ctx = WorkerCtx {
            t: 1,
            iters,
            transport: Box::new(client),
            controller: Arc::clone(&controller),
            delay: DelayModel::None,
            faults: FaultModel::None,
            sgd_fraction: None,
            time_scale: Duration::from_millis(100),
            sink: None,
            rng: rng1,
            gate: None,
            heartbeat: Some(Duration::from_millis(20)),
            resume: true,
            trace: None,
            metrics_stride: None,
        };
        let stats = run_worker(ctx, victim_compute.as_mut()).unwrap();
        assert_eq!(stats.updates, 70, "replacement does only the remainder");
    });
    handle.shutdown();

    assert_eq!(state.col_version(1), iters as u64, "task 1's budget fully landed");
    let f_cluster = p.objective(&server.final_w());
    assert!(
        (f_cluster - f_ref).abs() / f_ref.max(1e-9) < 0.05,
        "kill-and-replace cluster {f_cluster} vs in-proc {f_ref}"
    );
}
